"""PR 8: the floatless-wire static verifier + repo contract linter.

Three layers under test:

  * the §5.1 CHAIN PROOF (`repro.analysis.intervals.wire_chain_proof`) —
    symbolic intervals for encode → accumulate → pack → ring-sum → unpack,
    checked sound against concrete executions of the real wire codecs;
  * the JAXPR AUDITOR (`repro.analysis.wire_audit`) — planted-bug tests:
    each W-rule must flag its bug by rule id, and clean builds must not;
  * the AST LINTER (`repro.analysis.lint`) — C-rule unit tests on inline
    sources plus the repo-wide lint-clean check.
"""
import os
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from conftest import REPO, run_forced_mesh as _run

from repro.analysis import intervals as iv
from repro.analysis import jaxpr_walk as jw
from repro.analysis import lint as lint_mod
from repro.analysis import wire_audit as wa
from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.core import make_compressor
from repro.launch.step import build_train_step
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.parallel import collectives as coll
from repro.wire import make_wire_format

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# toy tracing helpers: a 1-device ("data",) mesh keeps the psum eqns in the
# jaxpr (vmap batching would erase them); the SPEC declares the worker count
# the static proof reasons about — the audit never looks at real devices.
# ---------------------------------------------------------------------------
def _toy_jaxpr(body, *structs):
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    sm = coll.shard_map(
        body, mesh=mesh, in_specs=(P(),) * len(structs), out_specs=P()
    )
    return jax.make_jaxpr(sm)(*structs)


def _spec(**kw):
    base = dict(
        dp_axes=("data",), axis_sizes={"data": 4}, n_workers=4,
        wire_kind="dense", bits=8,
    )
    base.update(kw)
    return wa.WireSpec(**base)


F32 = jax.ShapeDtypeStruct((4, 256), jnp.float32)


# ---------------------------------------------------------------------------
# W001: a float tensor on a reducing dp collective
# ---------------------------------------------------------------------------
def test_w001_raw_float_psum_flagged():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(x * 2.0, "data")  # the float-wire bug

    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32), _spec())
    assert not rep.ok
    w = [v for v in rep.violations if v.rule == "W001"]
    assert w, rep.violations
    assert "float32" in w[0].message and "psum" in w[0].where


def test_w001_scalar_loss_reduction_allowed():
    def step(x):
        loss = jnp.mean(x)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(loss, "data")  # scalar metrics are legal

    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32), _spec())
    assert rep.ok, rep.violations
    assert rep.stats["scalar_float_reduces"] >= 1


def test_w001_scalar_allowance_boundary():
    # the allowance is a NAMED constant with a pinned boundary: a float
    # reduce of exactly SCALAR_REDUCE_ALLOWANCE elements is a metric vector,
    # one element more is a float on the wire
    assert wa.SCALAR_REDUCE_ALLOWANCE == 64

    def reduce_n(n):
        struct = jax.ShapeDtypeStruct((n,), jnp.float32)

        def step(x):
            # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
            return lax.psum(x, "data")

        return wa.audit_jaxpr(_toy_jaxpr(step, struct), _spec())

    at_limit = reduce_n(wa.SCALAR_REDUCE_ALLOWANCE)
    assert at_limit.ok, at_limit.violations
    assert at_limit.stats["scalar_float_reduces"] >= 1

    over = reduce_n(wa.SCALAR_REDUCE_ALLOWANCE + 1)
    assert not over.ok
    assert [v.rule for v in over.violations] == ["W001"]
    assert "65 elements" in over.violations[0].message


def test_w001_bf16_param_all_gather_allowed():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.all_gather(x.astype(jnp.bfloat16), "data")

    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32), _spec())
    assert rep.ok, rep.violations  # gathers move data, they don't combine it


# ---------------------------------------------------------------------------
# W002: unbounded / overflowing integer wire
# ---------------------------------------------------------------------------
def test_w002_unclipped_int_wire_flagged():
    def step(x):
        ints = jnp.round(x * 1000.0).astype(jnp.int32)  # no §5.1 clip
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32), _spec(bits=32))
    assert not rep.ok
    w = [v for v in rep.violations if v.rule == "W002"]
    assert w and "not provably bounded" in w[0].message


def test_w002_degenerate_clip_257_contributions_int8():
    """127 // 257 == 0: every coordinate clips to zero.  The proof refuses
    the configuration outright — WireRangeError as a static property."""
    proof = iv.wire_chain_proof("dense", 8, 257)
    assert not proof.ok
    assert [c for c, _ in proof.violations] == ["degenerate-clip"]

    # and through the audit surface, attached to a clean jaxpr
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(jnp.mean(x), "data")

    rep = wa.audit_jaxpr(
        _toy_jaxpr(step, F32), _spec(n_workers=257, bits=8)
    )
    assert not rep.ok
    assert any(
        v.rule == "W002" and v.where == "chain:degenerate-clip"
        for v in rep.violations
    )


def test_w002_forgot_naccum_fails_reproof():
    """64 workers × 16 microbatches on int16 clips at clip_limit(n·M); a
    clip at clip_limit(n) alone overflows the pipelined lane sum."""
    ok = iv.wire_chain_proof("dense", 16, 64, 16)
    assert ok.ok, ok.violations
    loose = iv.safe_clip_limit(64, 16)  # forgot ×M
    bad = iv.wire_chain_proof("dense", 16, 64, 16, lim=loose)
    assert not bad.ok
    assert "lane-overflow" in [c for c, _ in bad.violations]


def test_w002_lane_overflow_loose_clip_flagged():
    def step(x):
        ints = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    # ±127 per worker is fine for n=1 but the declared spec says 4 workers
    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32), _spec())
    assert not rep.ok
    assert any(
        v.rule == "W002" and "lane" in v.message for v in rep.violations
    )


def test_w002_observed_clip_looser_than_packed_spec():
    """int32 lanes can't overflow a dtype check — only the observed-clip
    re-proof catches a clip looser than the packed guard-bit budget."""
    def step(x):
        ints = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int32)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    rep = wa.audit_jaxpr(
        _toy_jaxpr(step, F32), _spec(wire_kind="packed", bits=8)
    )
    assert rep.stats["clips_checked"] >= 1
    assert not rep.ok
    assert any(
        v.rule == "W002" and "looser than the declared" in v.message
        for v in rep.violations
    )


def test_w002_data_path_clip_not_mistaken_for_wire_clip():
    """A token-id style clip feeding the model through a gather must NOT be
    attributed to the wire (the clip-walk stops at non-wire primitives)."""
    def step(x, tok):
        tok = jnp.clip(tok, 0, 255)  # data-path clip, way out of §5.1 range
        emb = jnp.take(x, tok.reshape(-1) % 4, axis=0)
        g = jnp.round(emb)
        ints = jnp.clip(g, -31, 31).astype(jnp.int8)  # the real wire clip
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    tok_struct = jax.ShapeDtypeStruct((4, 16), jnp.int32)
    rep = wa.audit_jaxpr(_toy_jaxpr(step, F32, tok_struct), _spec())
    assert rep.ok, rep.violations  # 31 == clip_limit(4) — in contract
    assert rep.stats["clips_checked"] >= 1


# ---------------------------------------------------------------------------
# W003: fused route must consume packed words, not an HBM-sized image
# ---------------------------------------------------------------------------
def _fused_spec(**kw):
    return _spec(
        wire_kind="packed", bits=8, use_kernels=True, fused=True, **kw
    )


def test_w003_image_roundtrip_into_kernel_flagged():
    kops = pytest.importorskip("repro.kernels.ops")

    def step(image, param, mom):
        scal = jnp.ones((5,), jnp.float32)
        p, (m,), _ = kops.fused_apply(
            image, param, (mom,), scal, kernel="sgd", interpret=True
        )
        return p + 0.0 * m

    structs = (
        jax.ShapeDtypeStruct((1024,), jnp.int32),  # image-sized: the bug
        jax.ShapeDtypeStruct((1024,), jnp.float32),
        jax.ShapeDtypeStruct((1024,), jnp.float32),
    )
    closed = jax.make_jaxpr(step)(*structs)
    rep = wa.audit_jaxpr(closed, _fused_spec())
    assert rep.stats["pallas_calls"] >= 1
    assert any(v.rule == "W003" for v in rep.violations), rep.violations


def test_w003_packed_words_into_kernel_clean():
    kops = pytest.importorskip("repro.kernels.ops")

    def step(words, param, mom):
        scal = jnp.ones((5,), jnp.float32)
        p, (m,), _ = kops.fused_unpack_apply(
            words, param, (mom,), scal, None,
            kernel="sgd", bits=8, n_summed=4, interpret=True,
        )
        return p + 0.0 * m

    structs = (
        jax.ShapeDtypeStruct((256,), jnp.int32),  # 1024 int8 fields / 4
        jax.ShapeDtypeStruct((1024,), jnp.float32),
        jax.ShapeDtypeStruct((1024,), jnp.float32),
    )
    closed = jax.make_jaxpr(step)(*structs)
    rep = wa.audit_jaxpr(closed, _fused_spec())
    assert not [v for v in rep.violations if v.rule == "W003"], rep.violations


# ---------------------------------------------------------------------------
# suppression (audit side)
# ---------------------------------------------------------------------------
def test_audit_suppress_requires_justification():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(x, "data")

    closed = _toy_jaxpr(step, F32)
    with pytest.raises(ValueError, match="justification"):
        wa.audit_jaxpr(closed, _spec(), suppress={"W001": "  "})
    with pytest.raises(ValueError, match="unknown rule"):
        wa.audit_jaxpr(closed, _spec(), suppress={"W9": "x"})
    rep = wa.audit_jaxpr(
        closed, _spec(), suppress={"W001": "toy float wire,測定 only"}
    )
    assert rep.ok
    assert rep.suppressed and rep.suppressed[0][0].rule == "W001"


# ---------------------------------------------------------------------------
# clean real build: the audit passes on an actual train step, and
# build_train_step(verify="static") wires it in
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_clean_step_audit_passes(mesh11):
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    art = build_train_step(
        cfg, mesh11, shape,
        compressor=make_compressor("intsgd", bits=8, wire="packed8"),
        base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
        microbatches=2,
    )
    assert art.audit_spec is not None
    assert art.audit_spec.wire_kind == "packed"
    assert art.audit_spec.n_accum == 2
    rep = wa.audit_step(art)
    assert rep.ok, rep.violations
    assert rep.stats["int_wire_ops"] >= 1
    assert rep.stats["clips_checked"] >= 1


def test_build_train_step_verify_static(mesh11):
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    art = build_train_step(
        cfg, mesh11, shape,
        compressor=make_compressor("intsgd", wire="dense32"),
        base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
        verify="static",
    )
    assert art.audit_spec.wire_kind == "dense"
    with pytest.raises(ValueError, match="verify"):
        build_train_step(
            cfg, mesh11, shape,
            compressor=make_compressor("intsgd", wire="dense32"),
            base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
            verify="dynamic",
        )


def test_forced_mesh_audit_four_workers():
    """The real 4-device trace (ring transport included) passes the audit."""
    _run(
        textwrap.dedent(
            """
            import jax
            from repro.analysis import wire_audit
            from repro.configs import ShapeConfig, get_arch, smoke_config
            from repro.core import make_compressor
            from repro.launch.step import build_train_step
            from repro.optim import sgd
            from repro.optim.schedules import constant

            mesh = jax.make_mesh((4, 1), ("data", "model"))
            art = build_train_step(
                smoke_config(get_arch("xlstm-125m")), mesh,
                ShapeConfig("t", 32, 8, "train"),
                compressor=make_compressor("intsgd", bits=8, wire="packed8"),
                base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
                tp_override=1, overlap="ring", microbatches=2,
            )
            rep = wire_audit.audit_step(art)
            assert rep.ok, rep.violations
            assert rep.spec.n_workers == 4 and rep.spec.n_accum == 2
            assert rep.stats["int_wire_ops"] >= 1
            print("forced-mesh audit ok")
            """
        )
    )


# ---------------------------------------------------------------------------
# chain-proof soundness: concrete executions of the real codecs stay inside
# the statically derived stage intervals
# ---------------------------------------------------------------------------
def _concrete_chain(kind, bits, n, M, seed, size=64):
    """Run encode→accumulate→pack→wrap-sum→unpack with the real codec and
    return (per-stage concrete extrema, unpacked image, true sum)."""
    wf = make_wire_format(f"{kind}{bits}")
    rng = np.random.default_rng(seed)
    lim = iv.safe_clip_limit(n * M, bits)
    # per-worker M-microbatch accumulators of §5.1-clipped integers
    imgs = rng.integers(-lim, lim + 1, size=(n, M, size))
    accum = imgs.sum(axis=1)  # local M-sum, one per worker
    packed = [
        np.asarray(wf.pack(jnp.asarray(a, jnp.int32), n_workers=n))
        for a in accum
    ]
    wire = packed[0].astype(np.int32)
    partial_mags = [np.abs(wire).max()]
    for p in packed[1:]:
        wire = (wire.astype(np.int64) + p).astype(np.int32)  # wrap add
        partial_mags.append(np.abs(wire).max())
    out_shape = (size,) if kind == "packed" else accum[0].shape
    image = np.asarray(wf.unpack(jnp.asarray(wire), out_shape, n_summed=n))
    return {
        "encode": int(np.abs(imgs).max()),
        "accum": int(np.abs(accum).max()),
        "image": image.reshape(-1)[:size],
        "true": accum.sum(axis=0).reshape(-1)[:size],
        "partial_ok": kind == "packed" or max(partial_mags) <= iv.int_range_max(bits),
    }


_CHAIN_GRID = [
    (kind, bits, n, M)
    for kind, bits in (
        ("dense", 4), ("dense", 8), ("dense", 16), ("dense", 32),
        ("packed", 4), ("packed", 8), ("packed", 16),
    )
    for n in (1, 2, 4)
    for M in (1, 3)
    # degenerate points (clip_limit(n·M) == 0, e.g. int4 × 12 contributions)
    # are covered by test_w002_degenerate_clip_257_contributions_int8
    if iv.safe_clip_limit(n * M, bits) > 0
]


@pytest.mark.parametrize("kind,bits,n,M", _CHAIN_GRID)
def test_chain_proof_sound_vs_concrete(kind, bits, n, M):
    proof = iv.wire_chain_proof(kind, bits, n, M)
    assert proof.ok, proof.violations
    got = _concrete_chain(kind, bits, n, M, seed=hash((kind, bits, n, M)) % 2**31)
    assert got["encode"] <= proof.stages["encode"].mag
    assert got["accum"] <= proof.stages["accum"].mag
    assert got["partial_ok"]
    np.testing.assert_array_equal(got["image"], got["true"])
    assert proof.stages["image_sum"].contains(int(got["image"].min()))
    assert proof.stages["image_sum"].contains(int(got["image"].max()))


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        cfg=st.sampled_from(_CHAIN_GRID),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_chain_proof_sound_property(cfg, seed):
        kind, bits, n, M = cfg
        proof = iv.wire_chain_proof(kind, bits, n, M)
        got = _concrete_chain(kind, bits, n, M, seed)
        assert got["encode"] <= proof.stages["encode"].mag
        assert got["accum"] <= proof.stages["accum"].mag
        np.testing.assert_array_equal(got["image"], got["true"])
        assert proof.stages["image_sum"].contains(int(got["image"].min()))
        assert proof.stages["image_sum"].contains(int(got["image"].max()))


# ---------------------------------------------------------------------------
# interval evaluator unit checks
# ---------------------------------------------------------------------------
def test_interval_eval_scan_unrolled_exactly():
    def f(x):
        def body(c, _):
            return c + x, c

        out, ys = lax.scan(body, jnp.float32(0.0), None, length=5)
        return out, ys

    closed = jax.make_jaxpr(f)(jnp.float32(1.0))
    ivals = iv.eval_jaxpr_intervals(
        closed, [iv.Interval(0.0, 1.0)], axis_sizes={}
    )
    assert ivals[0].hi == 5.0  # 5 adds of [0,1], tracked exactly
    assert ivals[1].hi == 4.0  # ys union across iterations


def test_interval_eval_psum_scales_by_axis_product():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(x, "data")

    closed = _toy_jaxpr(step, jax.ShapeDtypeStruct((8,), jnp.float32))
    ivals = iv.eval_jaxpr_intervals(
        closed, [iv.Interval(-1.0, 1.0)], axis_sizes={"data": 4}
    )
    assert ivals[0].lo == -4.0 and ivals[0].hi == 4.0


# ---------------------------------------------------------------------------
# the contract linter (C-rules)
# ---------------------------------------------------------------------------
def _lint(src, path="src/repro/models/toy.py"):
    return lint_mod.lint_source(textwrap.dedent(src), path)


def test_c001_raw_collective_outside_shim():
    vs = _lint(
        """
        from jax import lax

        def f(x):
            return lax.psum(x, "data")
        """
    )
    assert [v.rule for v in vs] == ["C001"]
    assert "parallel/collectives" in vs[0].message


def test_c001_shim_module_itself_allowed():
    vs = _lint(
        """
        from jax import lax

        def psum(x, axes):
            return lax.psum(x, axes)
        """,
        path="src/repro/parallel/collectives.py",
    )
    assert vs == []


def test_c001_suppression_needs_justification():
    allowed = _lint(
        """
        from jax import lax

        def f(x):
            # lint: allow(C001) -- profiling probe, not a wire path
            return lax.psum(x, "data")
        """
    )
    assert allowed == []
    bare = _lint(
        """
        from jax import lax

        def f(x):
            # lint: allow(C001)
            return lax.psum(x, "data")
        """
    )
    assert any("justification" in v.message for v in bare)


def test_c002_optimizer_must_declare_wire_contract():
    vs = _lint(
        """
        from repro.optim.base import Optimizer

        opt = Optimizer(init=None, update=None)
        """
    )
    assert [v.rule for v in vs] == ["C002"]
    clean = _lint(
        """
        from repro.optim.base import Optimizer

        opt = Optimizer(
            init=None, update=None, dx_scale="eta", fused_kernel="sgd"
        )
        """
    )
    assert clean == []


def test_c003_wireformat_subclass_must_live_under_wire():
    vs = _lint(
        """
        from repro.wire.base import WireFormat

        class Rogue(WireFormat):
            pass
        """
    )
    assert [v.rule for v in vs] == ["C003"]
    clean = _lint(
        """
        from repro.wire.base import WireFormat

        class Fine(WireFormat):
            pass
        """,
        path="src/repro/wire/newcodec.py",
    )
    assert clean == []


def test_repo_is_lint_clean():
    # tests/ and benchmarks/ are linted too (PR 9): a harness that grows a
    # raw lax.psum must carry a justified `# lint: allow(C001)`
    trees = [SRC, os.path.join(REPO, "tests"), os.path.join(REPO, "benchmarks")]
    assert lint_mod.lint_paths([t for t in trees if os.path.isdir(t)]) == []


def test_lint_cli_is_jax_free():
    import subprocess
    import sys

    r = subprocess.run(
        [
            sys.executable, "-c",
            "import sys; import repro.analysis.lint; "
            "assert 'jax' not in sys.modules, 'lint imported jax'; "
            "print('ok')",
        ],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert r.returncode == 0, r.stderr


# ---------------------------------------------------------------------------
# walker regressions (the two fixed bugs ride the shared layer now)
# ---------------------------------------------------------------------------
def test_iter_eqns_covers_cond_sibling_subjaxprs():
    def f(x):
        def t(v):
            # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
            return lax.psum(v, "data")

        def fbr(v):
            return v * 2.0

        return lax.cond(x.sum() > 0, t, fbr, x)

    closed = _toy_jaxpr(f, jax.ShapeDtypeStruct((4,), jnp.float32))
    names = {e.primitive.name for e in jw.iter_eqns(closed.jaxpr)}
    assert "psum" in names  # the old walker could skip cond branches


def test_collectives_table_has_pmean():
    assert "pmean" in jw.COLLECTIVES  # missing from the pre-PR8 table
    assert "pmean" in jw.REDUCING_COLLECTIVES
