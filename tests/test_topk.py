"""TopKInt — the sparse integer wire: gather-transport round-trips, the
gather-safety contract (unpack of the stacked payloads == Σ local_image),
deterministic tie-breaking, the error-feedback residual it feeds, byte
agreement across the three meters (Logged / BucketManifest / the static
accountant), and the runtime (straggler / elastic) behavior."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import intervals as iv
from repro.analysis import traffic as tr
from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.scaling import AlphaState
from repro.parallel import collectives as coll
from repro.runtime.elastic import plan_after_failures
from repro.runtime.straggler import straggler_tolerant_sum
from repro.wire import (
    Logged,
    TopKInt,
    make_wire_format,
    payload_nbytes,
    wire_format_names,
)
from repro.wire.base import WireRangeError
from repro.wire.bucketing import plan_buckets

N = 4
AXIS = "workers"
CTX = CommCtx(axes=(AXIS,), axis_sizes=(N,))

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _rand_ints(wf, size, seed, n=1):
    lim = wf.clip_limit(n)
    return jax.random.randint(
        jax.random.PRNGKey(seed), (n, size), -lim, lim + 1, dtype=jnp.int32
    )


# ---------------------------------------------------------------------------
# round-trip and the gather-safety contract
# ---------------------------------------------------------------------------
def test_single_worker_roundtrip_is_local_image():
    wf = TopKInt(bits=8, k=5)
    ints = _rand_ints(wf, 37, 0)[0]
    payload = wf.pack(ints, n_workers=1)
    assert set(payload) == {"idx", "vals"}
    assert payload["idx"].dtype == jnp.int32
    assert payload["vals"].dtype == jnp.int32
    stacked = jax.tree.map(lambda p: p[None], payload)
    back = wf.unpack(stacked, (37,), n_summed=1)
    np.testing.assert_array_equal(
        np.asarray(back), np.asarray(wf.local_image(ints, n_workers=1))
    )


if HAVE_HYPOTHESIS:

    @given(
        bits=st.sampled_from([8, 16]),
        k=st.integers(1, 40),
        n=st.integers(1, 6),
        size=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_gather_aggregation_safety(bits, k, n, size, seed):
        """THE gather-safety contract: unpacking the n stacked payloads
        equals the elementwise sum of the n workers' top-k-masked images —
        for any clipped values, including the FULL-range boundary (topk's
        clip never divides by n) and k > leaf size."""
        wf = TopKInt(bits=bits, k=k)
        lim = wf.clip_limit(n)
        ints = _rand_ints(wf, size, seed, n=n)
        ints = ints.at[0].set(lim).at[-1].set(-lim)  # saturate both ways
        payloads = [wf.pack(ints[i], n_workers=n) for i in range(n)]
        stacked = jax.tree.map(lambda *ps: jnp.stack(ps), *payloads)
        got = wf.unpack(stacked, (size,), n_summed=n)
        want = sum(
            np.asarray(wf.local_image(ints[i], n_workers=n)) for i in range(n)
        )
        np.testing.assert_array_equal(np.asarray(got), want)


def test_tie_break_is_lowest_index():
    """|v| ties resolve toward the LOWER flat index — every worker, every
    re-trace, and the EF residual must agree on the mask."""
    wf = TopKInt(bits=8, k=2)
    ints = jnp.array([3, -5, 5, -5], jnp.int32)
    img = wf.local_image(ints, n_workers=1)
    np.testing.assert_array_equal(np.asarray(img), [0, -5, 5, 0])


def test_k_caps_at_leaf_size():
    wf = TopKInt(bits=8, k=64)
    assert wf.k_eff(3) == 3
    ints = jnp.array([1, -2, 3], jnp.int32)
    payload = wf.pack(ints, n_workers=1)
    assert payload["idx"].shape == (3,)
    stacked = jax.tree.map(lambda p: p[None], payload)
    np.testing.assert_array_equal(
        np.asarray(wf.unpack(stacked, (3,), n_summed=1)), [1, -2, 3]
    )


def test_full_range_clip_and_sign_extension():
    """clip_limit ignores n (nothing sums on the wire) and the boundary
    values survive the bit-packed two's-complement fields exactly."""
    for bits, lim in ((8, 127), (16, 32767)):
        wf = TopKInt(bits=bits, k=4)
        assert wf.clip_limit(1) == lim == wf.clip_limit(4096)
        ints = jnp.array([lim, -lim, 1, -1], jnp.int32)
        img = wf.local_image(ints, n_workers=1)
        np.testing.assert_array_equal(np.asarray(img), np.asarray(ints))
        stacked = jax.tree.map(lambda p: p[None], wf.pack(ints, n_workers=1))
        np.testing.assert_array_equal(
            np.asarray(wf.unpack(stacked, (4,), n_summed=1)),
            np.asarray(ints),
        )


def test_gather_safety_through_real_collective():
    """Same contract through CommCtx.psum_wire's gather dispatch under the
    vmap n-worker simulation; the decode also matches a dense int32 psum of
    the SAME masked images (decode parity on a shared mask)."""
    wf = TopKInt(bits=8, k=6)
    ints = _rand_ints(wf, 50, 3, n=N)

    def worker(v):
        _, s = CTX.psum_wire(v, wf)
        return s

    got = coll.vmap_workers(worker, in_axes=0)(ints)
    want = sum(
        np.asarray(wf.local_image(ints[i], n_workers=N)) for i in range(N)
    )
    for row in np.asarray(got):
        np.testing.assert_array_equal(row, want)

    # dense reference on the same mask
    masked = jnp.stack([wf.local_image(ints[i], n_workers=N) for i in range(N)])

    def dense_worker(v):
        return coll.psum(v, (AXIS,))

    dense = coll.vmap_workers(dense_worker, in_axes=0)(masked)
    np.testing.assert_array_equal(np.asarray(dense[0]), want)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_parses_parametric_names():
    wf = make_wire_format("topk8:64")
    assert wf == TopKInt(bits=8, k=64)
    assert make_wire_format("topk16:5") == TopKInt(bits=16, k=5)
    assert "topk8:<k>" in wire_format_names()
    for bad in ("topk8", "topk8:", "topk8:x", "topk8:0", "topk4:8"):
        with pytest.raises(ValueError):
            make_wire_format(bad)
    with pytest.raises(ValueError, match="unknown wire format"):
        make_wire_format("nope")


# ---------------------------------------------------------------------------
# bytes: Logged metering == manifest == static accountant == wire_bytes
# ---------------------------------------------------------------------------
def test_byte_meters_agree_on_gather_route():
    wf = TopKInt(bits=8, k=16)
    sizes = (129, 64, 7)
    tree = {f"l{i}": jnp.zeros((s,), jnp.int32) for i, s in enumerate(sizes)}

    logged = Logged(wf)
    payload = {k: logged.pack(v, n_workers=N) for k, v in tree.items()}
    declared = sum(wf.wire_bytes(s) for s in sizes)
    assert logged.pack_bytes == declared
    assert payload_nbytes(payload) == declared
    assert declared == sum(
        tr.payload_bytes("topk", 8, s, k=16) for s in sizes
    )

    manifest = plan_buckets(payload)
    assert manifest.payload_bytes == declared
    assert set(manifest.leaf_planes) == {"idx", "vals"}
    # gather collectives: one bucket, one dp axis of size N -> 1 eqn whose
    # operand is the whole bucket
    n_eqns, op_bytes = manifest.gather_collectives((N,))
    assert (n_eqns, op_bytes) == (len(manifest.bucket_sizes), declared)

    # unpack meters the gathered (n x) payload
    stacked = jax.tree.map(lambda p: jnp.stack([p] * N), payload)
    for name, leaf in tree.items():
        logged.unpack(stacked[name], leaf.shape, n_summed=N)
    assert logged.unpack_bytes == N * declared


def test_topk_beats_packed8_bytes_on_large_leaves():
    """The headline: at k << size the two-plane payload is far below
    packed8's size/4 words."""
    wf, packed = TopKInt(bits=8, k=64), make_wire_format("packed8")
    size = 10_000
    assert packed.wire_bytes(size) / wf.wire_bytes(size) > 4


# ---------------------------------------------------------------------------
# the EF residual through IntSGD
# ---------------------------------------------------------------------------
def _run_round(comp, grads, state=None):
    if state is None:
        state = comp.init(jax.tree.map(lambda x: x[0], grads))
        state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state
        )

    def worker(s, g):
        return comp.aggregate(
            s, g, key=jax.random.PRNGKey(7), eta=jnp.float32(0.1), ctx=CTX
        )

    return jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)


def test_intsgd_topk_state_carries_residual():
    comp = make_compressor("intsgd", bits=8, wire="topk8:4", stochastic=False)
    assert comp.fused_capable is False
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (N, 32))}
    state0 = comp.init({"w": grads["w"][0]})
    assert set(state0) == {"alpha", "ef"}
    assert isinstance(state0["alpha"], AlphaState)
    np.testing.assert_array_equal(np.asarray(state0["ef"]["w"]), 0.0)
    # a psum codec keeps the bare AlphaState (identical trajectory to seed)
    dense = make_compressor("intsgd", bits=8, wire="packed8")
    assert isinstance(dense.init({"w": grads["w"][0]}), AlphaState)
    assert dense.fused_capable is True


def test_intsgd_topk_residual_is_what_the_wire_dropped():
    """After one round, ef == work − local_image/α per worker — quantization
    AND sparsification error, both measured against the codec's own mask."""
    comp = make_compressor("intsgd", bits=8, wire="topk8:4", stochastic=False)
    wf = comp.wire_format
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (N, 32))}
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state
    )
    # warm α so the encode is non-degenerate
    state["alpha"] = AlphaState(
        r=jnp.full((N,), 1e-2), step=jnp.ones((N,), jnp.int32)
    )
    ghat, new_state, _ = _run_round(comp, grads, state)
    assert set(new_state) == {"alpha", "ef"}
    for i in range(N):
        s_i = jax.tree.map(lambda x: x[i], state)
        work = grads["w"][i]  # first round: ef == 0
        alphas = comp._alphas(
            s_i["alpha"], {"w": work}, jnp.float32(0.1), N, None
        )
        ints = wf.encode(
            work, alphas["w"], None, n_workers=N, stochastic=False
        )
        local = wf.local_image(ints, n_workers=N)
        want_ef = work - local.astype(jnp.float32) / alphas["w"]
        np.testing.assert_allclose(
            np.asarray(new_state["ef"]["w"][i]), np.asarray(want_ef),
            rtol=1e-5, atol=1e-6,
        )


def test_intsgd_topk_decode_is_sum_of_local_images():
    comp = make_compressor("intsgd", bits=8, wire="topk8:8", stochastic=False)
    wf = comp.wire_format
    grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (N, 24))}
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state
    )
    state["alpha"] = AlphaState(
        r=jnp.full((N,), 1e-2), step=jnp.ones((N,), jnp.int32)
    )
    ghat, _, _ = _run_round(comp, grads, state)
    s0 = jax.tree.map(lambda x: x[0], state)
    alphas = comp._alphas(
        s0["alpha"], {"w": grads["w"][0]}, jnp.float32(0.1), N, None
    )
    total = sum(
        np.asarray(wf.local_image(
            wf.encode(grads["w"][i], alphas["w"], None, n_workers=N,
                      stochastic=False),
            n_workers=N,
        ))
        for i in range(N)
    )
    want = total.astype(np.float32) / (N * np.asarray(alphas["w"]))
    np.testing.assert_allclose(
        np.asarray(ghat["w"][0]), want, rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# runtime: straggler exactness and elastic revalidation
# ---------------------------------------------------------------------------
def test_straggler_dead_worker_contributes_exact_zero():
    wf = TopKInt(bits=8, k=6)
    ints = _rand_ints(wf, 40, 5, n=N)
    alive = jnp.array([True, True, False, True])

    def worker(v, a):
        s, n_live = straggler_tolerant_sum(v, a, CTX, wf)
        return s, n_live

    got, n_live = coll.vmap_workers(worker, in_axes=(0, 0))(ints, alive)
    assert int(n_live[0]) == 3
    want = sum(
        np.asarray(wf.local_image(ints[i], n_workers=N))
        for i in range(N)
        if bool(alive[i])
    )
    np.testing.assert_array_equal(np.asarray(got[0]), want)


def test_elastic_revalidates_topk_decode_bound():
    plan = plan_after_failures(
        dp=4, tp=1, failed_devices=[3], global_batch=32, wire="topk16:32"
    )
    assert plan.n_dp == 3
    assert "revalidated" in plan.note and "k=32" in plan.note
    # n'·M·lim must fit int32: 70000 survivors x 32767 overflows
    with pytest.raises(WireRangeError, match="int32"):
        plan_after_failures(
            dp=70_001, tp=1, failed_devices=[0], global_batch=70_001,
            wire="topk16:32",
        )


# ---------------------------------------------------------------------------
# static layer: chain proof and fused gating
# ---------------------------------------------------------------------------
def test_chain_proof_topk_kind():
    proof = iv.wire_chain_proof("topk", 8, 4, 2)
    assert not proof.violations
    assert proof.lim == 127  # full range: the clip never divides by n·M
    # decode-side bound: n·M·32767 past int32 must be a violation
    bad = iv.wire_chain_proof("topk", 16, 70_000, 1)
    assert any("image" in c for c, _ in bad.violations), bad.violations


def test_fused_route_is_gated_off():
    wf = TopKInt(bits=8, k=4)
    assert wf.fused_capable is False
    with pytest.raises(NotImplementedError, match="fused_capable"):
        wf.fused_update(None, None, None, None, kernel=None, n_summed=N)
