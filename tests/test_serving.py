"""Serving engine: continuous batching completes all requests; greedy decode
is prefix-consistent."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, smoke_config
from repro.models.transformer import init_lm_params
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config(get_arch("granite-8b"))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_completes_requests(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    reqs = [
        Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new=5) for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert len(r.out) >= 5
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_engine_more_requests_than_slots(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[7, 8], max_new=3) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)


def test_identical_prompts_identical_outputs(small_model):
    """Greedy decode is deterministic: same prompt -> same continuation,
    regardless of slot assignment / batch composition."""
    cfg, params = small_model
    outs = []
    for trial in range(2):
        eng = ServeEngine(cfg, params, slots=2, max_seq=64)
        r = Request(rid=0, prompt=[11, 12, 13], max_new=6)
        eng.submit(r)
        if trial == 1:  # add a companion request to change batch composition
            eng.submit(Request(rid=1, prompt=[40], max_new=6))
        eng.run()
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]


def test_wire_delta_weight_refresh(small_model):
    """Train→serve weight sync over the integer wire: the trainer ships
    Δparams as packed transport words; the replica decodes and applies them
    within quantization tolerance — no float tensor ever crosses."""
    import numpy as np

    from repro.wire import PackedInt

    cfg, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    wf = PackedInt(bits=8)
    key = jax.random.PRNGKey(7)
    alpha = jnp.float32(1000.0)
    deltas = jax.tree.map(
        lambda p: 1e-3 * jax.random.normal(
            jax.random.fold_in(key, p.size), p.shape
        ),
        params,
    )
    words = jax.tree.map(
        lambda d: wf.pack(
            wf.encode(d, alpha, key, n_workers=1), n_workers=1
        ),
        deltas,
    )
    for w in jax.tree.leaves(words):
        assert jnp.issubdtype(w.dtype, jnp.integer)  # floatless wire
    before = jax.tree.map(jnp.copy, eng.params)
    eng.apply_wire_delta(words, jax.tree.map(lambda _: alpha, deltas), wf)
    for b, a, d in zip(
        jax.tree.leaves(before), jax.tree.leaves(eng.params),
        jax.tree.leaves(deltas),
    ):
        got = np.asarray(a, np.float32) - np.asarray(b, np.float32)
        # quantization error <= 1/alpha per coordinate (plus clip, absent
        # here: |alpha*d| << 127)
        assert np.abs(got - np.asarray(d)).max() <= 1.0 / float(alpha) + 1e-6
