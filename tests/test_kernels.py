"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exact where
the math is exact, allclose where FMA reassociation applies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(7,), (128,), (1000,), (8, 128), (300, 700), (3, 5, 7), (2, 3, 4, 5)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", [8, 32])
@pytest.mark.parametrize("stochastic", [True, False])
def test_int_compress_matches_oracle(shape, bits, stochastic):
    key = jax.random.PRNGKey(hash((shape, bits)) % 2**31)
    x = jax.random.normal(key, shape, jnp.float32) * 5.0
    alpha = jnp.float32(23.7)
    seed = ops.seed_from_key(key)
    got = ops.int_compress(
        x, alpha, key, n_workers=4, bits=bits, stochastic=stochastic
    )
    want = ref.int_compress_ref(
        x, alpha, seed, n_workers=4, bits=bits, stochastic=stochastic
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int_compress_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (333,), jnp.float32).astype(dtype)
    got = ops.int_compress(x, jnp.float32(100.0), key, n_workers=2)
    want = ref.int_compress_ref(
        x, jnp.float32(100.0), ops.seed_from_key(key), n_workers=2
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_compress_unbiased_statistics():
    """Kernel's stochastic rounding is unbiased: mean(Int(αx)/α) ≈ mean(x)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (200_000,))
    alpha = jnp.float32(3.0)
    ints = ops.int_compress(x, alpha, key, n_workers=1)
    err = float(jnp.mean(ints.astype(jnp.float32) / alpha - x))
    assert abs(err) < 1e-3


@pytest.mark.parametrize("shape", [(7,), (128,), (300, 700), (3, 5, 7)])
@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pack_words_matches_oracle(shape, bits):
    """Pallas pack kernel vs the independent uint32-mul oracle, bit-exact."""
    key = jax.random.PRNGKey(hash((shape, bits)) % 2**31)
    lim = ref._INT_LIM[bits] // 4
    ints = jax.random.randint(key, shape, -lim, lim + 1)
    got = ops.pack_words(ints, bits=bits, n_workers=4)
    want = ref.pack_words_ref(ints, bits=bits, n_workers=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(7,), (1000,), (33, 9)])
@pytest.mark.parametrize("bits", [4, 8])
def test_unpack_words_matches_oracle_after_sum(shape, bits):
    """Unpack kernel inverts a 4-worker wrap-around word sum, bit-exact."""
    n = 4
    key = jax.random.PRNGKey(hash((shape, bits)) % 2**31)
    lim = ref._INT_LIM[bits] // n
    size = int(np.prod(shape))
    ints = jax.random.randint(key, (n, size), -lim, lim + 1)
    wsum = sum(
        ops.pack_words(ints[i].reshape(shape), bits=bits, n_workers=n)
        for i in range(n)
    )
    got = ops.unpack_words(wsum, shape, bits=bits, n_summed=n)
    want = ref.unpack_words_ref(wsum, shape, bits=bits, n_summed=n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.sum(ints, axis=0).reshape(shape))
    )


@pytest.mark.parametrize("shape", [(64,), (513, 300)])
def test_fused_unpack_update_matches_oracle(shape):
    """The packed-wire fused kernel == unpack + fused-update composition."""
    n, bits = 4, 8
    key = jax.random.PRNGKey(11)
    lim = ref._INT_LIM[bits] // n
    size = int(np.prod(shape))
    ints = jax.random.randint(key, (n, size), -lim, lim + 1)
    wsum = sum(
        ops.pack_words(ints[i].reshape(shape), bits=bits, n_workers=n)
        for i in range(n)
    )
    p = jax.random.normal(key, shape)
    m = jax.random.normal(jax.random.fold_in(key, 1), shape)
    got_p, got_m = ops.fused_unpack_update(
        wsum, p, m, 1e-3, 0.1, 0.9, 1e-4, bits=bits, n_summed=n
    )
    want_p, want_m = ref.fused_unpack_update_ref(
        wsum, p, m, bits=bits, n_summed=n,
        inv_nalpha=jnp.float32(1e-3), lr=jnp.float32(0.1),
        mu=jnp.float32(0.9), wd=jnp.float32(1e-4),
    )
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", [(64,), (513, 300), (4, 4, 4)])
def test_fused_update_matches_oracle(shape):
    key = jax.random.PRNGKey(1)
    ints = jax.random.randint(key, shape, -1000, 1000)
    p = jax.random.normal(key, shape)
    m = jax.random.normal(jax.random.fold_in(key, 1), shape)
    got_p, got_m = ops.fused_update(ints, p, m, 1e-3, 0.1, 0.9, 1e-4)
    want_p, want_m = ref.fused_update_ref(
        ints, p, m,
        inv_nalpha=jnp.float32(1e-3), lr=jnp.float32(0.1),
        mu=jnp.float32(0.9), wd=jnp.float32(1e-4),
    )
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)


def _adamw_scalars(*, inv_nalpha, clip, lr, b1, b2, eps, wd, t):
    """Canonical adamw scalar vector (kernels/fused_update.py layout)."""
    return jnp.stack([
        jnp.float32(inv_nalpha), jnp.float32(clip), jnp.float32(lr),
        jnp.float32(b1), jnp.float32(1.0 - b1), jnp.float32(b2),
        jnp.float32(1.0 - b2), jnp.float32(eps), jnp.float32(wd),
        jnp.float32(1.0 - b1**t), jnp.float32(1.0 - b2**t),
    ])


@pytest.mark.parametrize("shape", [(64,), (513, 300)])
@pytest.mark.parametrize("with_shift", [False, True])
def test_fused_unpack_adamw_matches_oracle(shape, with_shift):
    """fused_unpack_adamw_2d == unpack + bias-corrected AdamW composition,
    with and without the IntDIANA global shift (whose new value must be the
    UNCLIPPED decoded aggregate)."""
    n, bits, t = 4, 8, 3
    key = jax.random.PRNGKey(13)
    lim = ref._INT_LIM[bits] // n
    size = int(np.prod(shape))
    ints = jax.random.randint(key, (n, size), -lim, lim + 1)
    wsum = sum(
        ops.pack_words(ints[i].reshape(shape), bits=bits, n_workers=n)
        for i in range(n)
    )
    p = jax.random.normal(key, shape)
    mu = jax.random.normal(jax.random.fold_in(key, 1), shape) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), shape)) * 0.01
    h = (jax.random.normal(jax.random.fold_in(key, 3), shape) * 0.3
         if with_shift else None)
    kw = dict(inv_nalpha=1e-3, lr=0.05, b1=0.9, b2=0.95, eps=1e-8, wd=0.1)
    sc = _adamw_scalars(clip=0.7, t=t, **kw)
    got_p, (got_m, got_v), got_h = ops.fused_unpack_apply(
        wsum, p, (mu, nu), sc, h, kernel="adamw", bits=bits, n_summed=n
    )
    want_p, want_m, want_v, want_h = ref.fused_unpack_adamw_ref(
        wsum, p, mu, nu, bits=bits, n_summed=n,
        clip=jnp.float32(0.7), shift=h,
        bc1=jnp.float32(1.0 - 0.9**t), bc2=jnp.float32(1.0 - 0.95**t),
        **{k: jnp.float32(v) for k, v in kw.items()},
    )
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-7)
    if with_shift:
        np.testing.assert_allclose(got_h, want_h, rtol=1e-5, atol=1e-6)
    else:
        assert got_h is None


@given(st.integers(1, 3000), st.integers(1, 60), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_fused_adamw_kernel_matches_optimizer_update(size, t, seed):
    """Property: the fused AdamW kernel reproduces the REFERENCE optimizer
    (optim/adamw.py::update — the exact arithmetic the unfused ZeRO-1 route
    runs) on random integer images, for any size and step count."""
    from repro.optim import adamw

    key = jax.random.PRNGKey(seed)
    ints = jax.random.randint(key, (size,), -4 * 127, 4 * 127 + 1)
    p = jax.random.normal(jax.random.fold_in(key, 1), (size,))
    mu = jax.random.normal(jax.random.fold_in(key, 2), (size,)) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (size,))) * 0.01
    inv_nalpha, lr = 2.5e-3, 0.07
    opt = adamw()  # b1=0.9, b2=0.95, eps=1e-8, wd=0.1
    h = opt.hyper
    state = {"mu": {"w": mu}, "nu": {"w": nu},
             "count": jnp.asarray(t - 1, jnp.int32)}
    g = {"w": ints.astype(jnp.float32) * inv_nalpha}
    upd, st2 = opt.update(g, state, {"w": p}, jnp.float32(lr))
    want_p = p + upd["w"]
    sc = _adamw_scalars(
        inv_nalpha=inv_nalpha, clip=1.0, lr=lr, b1=h["b1"], b2=h["b2"],
        eps=h["eps"], wd=h["weight_decay"], t=t,
    )
    got_p, (got_m, got_v), _ = ops.fused_apply(
        ints, p, (mu, nu), sc, kernel="adamw"
    )
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, st2["mu"]["w"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, st2["nu"]["w"], rtol=1e-5, atol=1e-7)


def test_fused_sgd_shift_emits_decoded_aggregate():
    """SGD kernel with the IntDIANA shift: new shift == h + Σints·inv_nα
    (unclipped), while the update consumes clip·(h + Σints·inv_nα)."""
    key = jax.random.PRNGKey(5)
    ints = jax.random.randint(key, (1000,), -500, 500)
    p = jax.random.normal(key, (1000,))
    m = jax.random.normal(jax.random.fold_in(key, 1), (1000,))
    h = jax.random.normal(jax.random.fold_in(key, 2), (1000,)) * 0.2
    inv_nalpha, clip, lr, mu, wd = 2e-3, 0.6, 0.05, 0.9, 1e-4
    sc = jnp.stack([jnp.float32(x) for x in (inv_nalpha, clip, lr, mu, wd)])
    got_p, (got_m,), got_h = ops.fused_apply(
        ints, p, (m,), sc, h, kernel="sgd"
    )
    g_agg = ints * inv_nalpha + h
    m2 = mu * m + (clip * g_agg + wd * p)
    np.testing.assert_allclose(got_h, g_agg, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, m2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_p, p - lr * m2, rtol=1e-5, atol=1e-6)


def test_fused_update_equals_sgd_semantics():
    """Fused kernel == decode + torch-SGD reference sequence."""
    key = jax.random.PRNGKey(2)
    ints = jax.random.randint(key, (1000,), -500, 500)
    p = jax.random.normal(key, (1000,))
    m = jnp.zeros((1000,))
    inv_nalpha, lr, mu, wd = 2e-3, 0.05, 0.9, 1e-4
    got_p, got_m = ops.fused_update(ints, p, m, inv_nalpha, lr, mu, wd)
    g = ints * inv_nalpha + wd * p
    m2 = mu * m + g
    p2 = p - lr * m2
    np.testing.assert_allclose(got_p, p2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, m2, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 5000), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_block_norms_property(size, nblocks):
    """Sum of block norms == total ||x||² for any size/block split."""
    x = jax.random.normal(jax.random.PRNGKey(size), (size,))
    bn = ops.block_sq_norms(x, nblocks)
    assert bn.shape == (nblocks,)
    np.testing.assert_allclose(
        float(jnp.sum(bn)), float(jnp.sum(x * x)), rtol=1e-4
    )


def test_sq_norm_kernel():
    x = jax.random.normal(jax.random.PRNGKey(0), (2048, 130))
    np.testing.assert_allclose(
        float(ops.sq_norm(x)), float(jnp.sum(x * x)), rtol=1e-5
    )
