"""Multi-device integration tests (forced 4-CPU-device subprocess):
shard_map train step learns, TP cross-entropy matches unsharded reference,
pipeline parallelism matches sequential execution."""
import pytest

from conftest import run_forced_mesh as _run


@pytest.mark.slow
def test_shard_map_train_learns_and_matches_reference():
    out = _run(
        """
import jax, jax.numpy as jnp
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step, build_init_state
from repro.launch.inputs import materialize_batch
from repro.models.transformer import init_lm_params
from repro.optim import sgd
from repro.optim.schedules import constant

mesh = jax.make_mesh((2, 2), ("data", "model"))
tr = ShapeConfig("t", 64, 4, "train")
cfg = smoke_config(get_arch("granite-8b"))
comp = make_compressor("intsgd")
opt = sgd(momentum=0.9)
art = build_train_step(cfg, mesh, tr, compressor=comp, base_opt=opt,
                       lr_schedule=constant(0.5), param_dtype=jnp.float32)
key = jax.random.PRNGKey(0)
params = init_lm_params(key, cfg, tp=2, n_shards=1, dtype=jnp.float32)
params = jax.device_put(params, art.in_shardings[0])
init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt)
opt_state, comp_state = init(params)
batch = materialize_batch(cfg, tr, key)
losses = []
for i in range(15):
    fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
    params, opt_state, comp_state, loss, metrics = fn(
        params, opt_state, comp_state, jnp.int32(i), jax.random.fold_in(key, i), batch)
    losses.append(float(loss))
assert losses[-1] < losses[0] - 1.0, losses
print("LEARN_OK", losses[0], losses[-1])
"""
    )
    assert "LEARN_OK" in out


@pytest.mark.slow
def test_tp_cross_entropy_matches_dense():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models.common import Axes, tp_cross_entropy
from repro.parallel.collectives import shard_map

mesh = jax.make_mesh((4,), ("model",))
V, B = 32, 8
key = jax.random.PRNGKey(0)
logits = jax.random.normal(key, (B, V))
labels = jax.random.randint(key, (B,), 0, V)

def f(lg, lb):
    axes = Axes(tp="model", tp_size=4)
    return tp_cross_entropy(lg, lb, axes)

sharded = jax.jit(shard_map(f, mesh=mesh,
    in_specs=(P(None, "model"), P()), out_specs=P(), check_vma=False))
got = sharded(logits, labels)
want = -jax.nn.log_softmax(logits)[jnp.arange(B), labels]
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-5)
print("CE_OK")
"""
    )
    assert "CE_OK" in out


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import shard_map
from repro.parallel.pp import pipeline_forward

mesh = jax.make_mesh((4,), ("stage",))
L, D, MB, NM = 8, 16, 4, 6
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) * 0.2
x = jax.random.normal(jax.random.fold_in(key, 1), (NM, MB, D))

layer = lambda w, h: jnp.tanh(h @ w)

# sequential reference
ref = x
for l in range(L):
    ref = layer(ws[l], ref)

def staged(w_stage, xm):
    return pipeline_forward(layer, w_stage, xm, axis="stage", n_stages=4)

out = jax.jit(shard_map(staged, mesh=mesh,
    in_specs=(P("stage"), P()), out_specs=P("stage"), check_vma=False))(ws, x)
# outputs are valid on the LAST stage only (GPipe drain) — compare its slice
out = out.reshape(4, NM, MB, D)[3]
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("PP_OK")
"""
    )
    assert "PP_OK" in out


@pytest.mark.slow
def test_seq_sharded_decode_matches_batch_replicated():
    """Distributed online-softmax over a dp-sharded KV cache must equal the
    single-device decode."""
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import attention as A
from repro.models.common import Axes, plan_heads
from repro.parallel.collectives import shard_map

layout = plan_heads(4, 2, 8, 1)
key = jax.random.PRNGKey(0)
params = A.init_attn_params(key, 16, layout)
B, S = 2, 32
x = jax.random.normal(key, (B, 1, 16))
pos = jnp.full((B,), S // 2, jnp.int32)
# reference: single device, full cache
cache = A.init_cache(B, S, layout, jnp.float32)
kv = jax.random.normal(jax.random.fold_in(key, 1), (B, S, layout.kv_local, layout.head_dim))
cache["k"] = kv; cache["v"] = kv * 0.5
cache["kv_pos"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
ref, _ = A.attention_decode(params, x, pos, cache, Axes(), layout)

mesh = jax.make_mesh((4,), ("data",))
def f(p, xx, pp, c):
    axes = Axes(sp=("data",), sp_sizes=(4,))
    o, _ = A.attention_decode(p, xx, pp, c, axes, layout)
    return o
spec_c = {"k": P(None, "data"), "v": P(None, "data"), "kv_pos": P(None, "data")}
got = jax.jit(shard_map(f, mesh=mesh,
    in_specs=(P(), P(), P(), spec_c), out_specs=P(), check_vma=False))(
    params, x, pos, cache)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-5)
print("SP_OK")
"""
    )
    assert "SP_OK" in out


_FUSED_FAMILY_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step, build_init_state
from repro.launch.inputs import materialize_batch
from repro.models.transformer import init_lm_params
from repro.optim import adamw, sgd
from repro.optim.schedules import constant

N_DP = 4
mesh = jax.make_mesh((4, 1), ("data", "model"))
tr = ShapeConfig("t", 32, 4, "train")
cfg = smoke_config(get_arch("xlstm-125m"))
key = jax.random.PRNGKey(0)

def run(wire, fused, overlap):
    comp = make_compressor(%(comp)s)
    opt = adamw()
    art = build_train_step(cfg, mesh, tr, compressor=comp, base_opt=opt,
                           lr_schedule=constant(0.01), param_dtype=jnp.float32,
                           fused=fused, donate=False, wire=wire,
                           overlap=overlap, bucket_words=2048)
    params = init_lm_params(key, cfg, tp=1, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt,
                            fused=fused)
    opt_state, comp_state = init(params)
    batch = materialize_batch(cfg, tr, key)
    losses = []
    for i in range(5):
        fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
        params, opt_state, comp_state, loss, _ = fn(
            params, opt_state, comp_state, jnp.int32(i),
            jax.random.fold_in(key, i), batch)
        losses.append(float(loss))
    return params, opt_state, comp_state, losses

def pad_rows(x, n):
    flat = np.asarray(x, np.float32).reshape(-1)
    per = (flat.size + n - 1) // n * n
    return np.pad(flat, (0, per - flat.size)).reshape(n, per // n)

def moment_rows(opt_state, fused, name):
    # both routes as (n_dp, k/n_dp) f32 rows: the fused route's replicated
    # tensor resharded like the ZeRO-1 master layout
    if fused:
        return [pad_rows(l, N_DP) for l in jax.tree.leaves(opt_state[name])]
    return [np.asarray(l, np.float32)
            for l in jax.tree.leaves(opt_state["base"][name])]

allclose = lambda a, b: np.testing.assert_allclose(
    np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6)

for wire in ("dense8", "packed8"):
    for overlap in ("off", "ring"):
        p_u, o_u, c_u, l_u = run(wire, False, overlap)
        p_f, o_f, c_f, l_f = run(wire, True, overlap)
        np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_u),
                                   rtol=1e-6)
        for a, b in zip(jax.tree.leaves(p_u), jax.tree.leaves(p_f)):
            allclose(a, b)
        # moment state parity: fused in-register EMAs == ZeRO-1 sharded EMAs
        for nm in ("mu", "nu"):
            for a, b in zip(moment_rows(o_u, False, nm),
                            moment_rows(o_f, True, nm)):
                allclose(a, b)
        assert int(o_u["base"]["count"]) == int(o_f["count"]) == 5
        # compressor state parity (IntDIANA shifts ride the fused kernel)
        for a, b in zip(jax.tree.leaves(c_u), jax.tree.leaves(c_f)):
            allclose(a, b)
        print("PARITY", wire, overlap)
print("FUSED_FAMILY_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize(
    "comp",
    ['"intsgd8"', '"intdiana", bits=8'],
    ids=["adamw_intsgd8", "adamw_intdiana"],
)
def test_fused_family_parity_on_mesh(comp):
    """ULP parity for the new fused routes on the REAL 4-device mesh:
    {AdamW}×{IntSGD, IntDIANA}×{dense8, packed8}×{overlap off, ring}, 5
    steps, fused (Pallas decode+AdamW, moments in-register, IntDIANA shift
    advanced inside the kernel) vs unfused (decode + ZeRO-1 AdamW) — losses,
    params, BOTH Adam moments, the step count and the DIANA shift state all
    compared."""
    out = _run(_FUSED_FAMILY_SCRIPT % {"comp": comp}, timeout=1200)
    assert "FUSED_FAMILY_OK" in out


@pytest.mark.slow
def test_packed_wire_parity_on_mesh():
    """ULP parity on the REAL 4-device mesh: build_train_step over the
    PackedInt wire matches the DenseInt route step-for-step, on both the
    unfused (ZeRO-1) and fused (Pallas packed-word decode) routes. The
    integer image is bit-identical by the shared §5.1 clip; only the
    transport words on the psum differ."""
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step, build_init_state
from repro.launch.inputs import materialize_batch
from repro.models.transformer import init_lm_params
from repro.optim import sgd
from repro.optim.schedules import constant

mesh = jax.make_mesh((4, 1), ("data", "model"))
tr = ShapeConfig("t", 32, 4, "train")
cfg = smoke_config(get_arch("xlstm-125m"))
key = jax.random.PRNGKey(0)

def run(wire, fused):
    comp = make_compressor("intsgd8")
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    art = build_train_step(cfg, mesh, tr, compressor=comp, base_opt=opt,
                           lr_schedule=constant(0.2), param_dtype=jnp.float32,
                           fused=fused, donate=False, wire=wire)
    params = init_lm_params(key, cfg, tp=1, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt, fused=fused)
    opt_state, comp_state = init(params)
    batch = materialize_batch(cfg, tr, key)
    losses = []
    for i in range(4):
        fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
        params, opt_state, comp_state, loss, _ = fn(
            params, opt_state, comp_state, jnp.int32(i),
            jax.random.fold_in(key, i), batch)
        losses.append(float(loss))
    return params, losses

for fused in (False, True):
    p_d, l_d = run("dense8", fused)
    p_p, l_p = run("packed8", fused)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_d), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_d), jax.tree.leaves(p_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=2e-6)
print("PACKED_PARITY_OK")
"""
    )
    assert "PACKED_PARITY_OK" in out
