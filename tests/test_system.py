"""End-to-end behaviour tests for the system: train loop with checkpointing
+ resume, data determinism, public API integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.data.synthetic import SyntheticLMData
from repro.launch.train import train_loop


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_synthetic_data_deterministic():
    d = SyntheticLMData(vocab=128, seq_len=16, batch_per_worker=4, seed=3)
    a = d.batch(7, 2)
    b = d.batch(7, 2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = d.batch(8, 2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # labels are next-token with tail masked
    np.testing.assert_array_equal(
        np.asarray(a["labels"][:, :-1]), np.asarray(a["tokens"][:, 1:])
    )
    assert (np.asarray(a["labels"][:, -1]) == -1).all()


@pytest.mark.slow
def test_train_loop_learns(mesh):
    cfg = smoke_config(get_arch("granite-8b"))
    shape = ShapeConfig("t", 32, 4, "train")
    _, losses = train_loop(
        cfg, mesh, shape, compressor="intsgd", steps=40, lr=0.5, log_every=100
    )
    # fresh data each step (real SGD on the synthetic stream, 5 warmup steps)
    assert losses[-1] < losses[0] - 0.6, (losses[0], losses[-1])


@pytest.mark.slow
def test_checkpoint_resume_continues_exactly(mesh, tmp_path):
    """Kill-and-resume: the resumed run continues from the checkpointed
    state (same step-indexed data, same losses modulo rounding noise)."""
    cfg = smoke_config(get_arch("granite-8b"))
    shape = ShapeConfig("t", 32, 4, "train")
    store = CheckpointStore(str(tmp_path), async_writes=False)
    _, losses_a = train_loop(
        cfg, mesh, shape, compressor="intsgd", steps=20, lr=0.5,
        ckpt=store, ckpt_every=10, log_every=100,
    )
    assert store.latest_step() == 20
    # resume from step 20 and train 10 more
    _, losses_b = train_loop(
        cfg, mesh, shape, compressor="intsgd", steps=30, lr=0.5,
        ckpt=store, ckpt_every=10, resume=True, log_every=100,
    )
    # it picked up where it left off and kept improving
    assert len(losses_b) == 10
    assert min(losses_b) < losses_a[-1] + 0.25


@pytest.mark.slow
def test_train_loop_intdiana(mesh):
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    _, losses = train_loop(
        cfg, mesh, shape, compressor="intdiana", steps=20, lr=0.3, log_every=100
    )
    assert losses[-1] < losses[0] - 0.5
