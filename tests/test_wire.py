"""The wire-codec subsystem: pack/unpack round-trips, the psum-safety
contract (psum-over-packed-words == pack-of-summed-ints under the §5.1
clip), codec-parity of the compressors, and the degenerate-range guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.rounding import WireRangeError, clip_for_wire, clip_limit
from repro.parallel import collectives as coll
from repro.wire import DenseInt, Logged, PackedInt, make_wire_format

N = 4
AXIS = "workers"
CTX = CommCtx(axes=(AXIS,), axis_sizes=(N,))

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip, example-based tests still run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# pack/unpack round-trip and sum-safety (hypothesis: all widths × odd shapes
# × negative values)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @given(
        bits=st.sampled_from([4, 8, 16]),
        n=st.integers(1, 6),
        size=st.integers(1, 700),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_pack_unpack_roundtrip(bits, n, size, seed):
        """unpack(pack(v), n_summed=1 payload) recovers v exactly for any
        clipped integer image — including odd sizes that pad the last word
        and values at the negative clip boundary."""
        wf = PackedInt(bits=bits)
        lim = wf.clip_limit(n)
        ints = jax.random.randint(
            jax.random.PRNGKey(seed), (size,), -lim, lim + 1
        )
        words = wf.pack(ints, n_workers=n)
        assert words.dtype == jnp.int32
        assert words.size == -(-size // (32 // bits))
        # a single packed payload is "a sum over n where n-1 workers sent 0"
        zeros = wf.pack(jnp.zeros((size,), jnp.int32), n_workers=n)
        total = words + (n - 1) * zeros
        back = wf.unpack(total, (size,), n_summed=n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(ints))

    @given(
        bits=st.sampled_from([4, 8, 16]),
        n=st.integers(2, 6),
        size=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_packed_sum_safety(bits, n, size, seed):
        """THE psum-safety contract: the wrap-around int32 sum of n packed
        payloads unpacks to the elementwise sum of the n integer images, for
        any values under the §5.1 clip (worst case: all workers at ±lim)."""
        wf = PackedInt(bits=bits)
        lim = wf.clip_limit(n)
        key = jax.random.PRNGKey(seed)
        ints = jax.random.randint(key, (n, size), -lim, lim + 1)
        # adversarial rows: saturate the clip in both directions
        ints = ints.at[0].set(lim).at[-1].set(-lim)
        words = jnp.stack([wf.pack(ints[i], n_workers=n) for i in range(n)])
        wsum = jnp.sum(words, axis=0)  # int32 wrap-around, like the psum
        got = wf.unpack(wsum, (size,), n_summed=n)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(jnp.sum(ints, axis=0))
        )


def test_packed_sum_safety_through_real_psum():
    """Same contract through the actual collective: vmap(axis_name) psum of
    packed words == pack of summed ints (the simulation lowers the identical
    lax.psum the mesh wire uses)."""
    wf = PackedInt(bits=8)
    lim = wf.clip_limit(N)
    ints = jax.random.randint(jax.random.PRNGKey(3), (N, 1003), -lim, lim + 1)

    def worker(v):
        words = wf.pack(v, n_workers=N)
        wsum = coll.psum_tree(words, (AXIS,))
        return wf.unpack(wsum, (v.shape[-1],), n_summed=N)

    got = coll.vmap_workers(worker, in_axes=0)(ints)
    want = jnp.sum(ints, axis=0)
    for i in range(N):
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


def test_packed_encode_identical_to_dense():
    """PackedInt and DenseInt share the §5.1 clip: the integer image is
    bit-identical, only the transport differs — the invariant behind the
    step-for-step ULP parity of the two routes."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (513,)) * 3.0
    for bits in (4, 8, 16):
        d = DenseInt(bits=bits).encode(
            x, jnp.float32(9.7), key, n_workers=N
        )
        p = PackedInt(bits=bits).encode(
            x, jnp.float32(9.7), key, n_workers=N
        )
        np.testing.assert_array_equal(np.asarray(d), np.asarray(p))


def test_dense_pack_is_exact_narrowing():
    wf = DenseInt(bits=8)
    ints = jnp.arange(-31, 32, dtype=jnp.int32)
    words = wf.pack(ints, n_workers=N)
    assert words.dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(wf.unpack(words, ints.shape, n_summed=N)), np.asarray(ints)
    )


def test_kernel_pack_matches_jnp_pack():
    """use_kernels routes pack/unpack through the Pallas kernels with the
    identical canonical word layout."""
    for bits in (4, 8, 16):
        ref_wf = PackedInt(bits=bits)
        ker_wf = PackedInt(bits=bits, use_kernels=True)
        lim = ref_wf.clip_limit(N)
        ints = jax.random.randint(
            jax.random.PRNGKey(bits), (777,), -lim, lim + 1
        )
        w_ref = ref_wf.pack(ints, n_workers=N)
        w_ker = ker_wf.pack(ints, n_workers=N)
        np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_ker))
        zeros = ref_wf.pack(jnp.zeros_like(ints), n_workers=N)
        total = w_ref + (N - 1) * zeros
        np.testing.assert_array_equal(
            np.asarray(ref_wf.unpack(total, (777,), n_summed=N)),
            np.asarray(ker_wf.unpack(total, (777,), n_summed=N)),
        )


# ---------------------------------------------------------------------------
# degenerate §5.1 range (regression: silently zeroed gradients)
# ---------------------------------------------------------------------------
def test_clip_for_wire_degenerate_range_raises():
    """256 workers on an int8 wire: the old code clipped every integer to 0
    (lim = 127//256 == 0), silently zeroing the gradient. Now it's an error
    naming the fix."""
    with pytest.raises(WireRangeError, match="widen|wider"):
        clip_for_wire(jnp.ones((4,)), n_workers=256, bits=8)
    with pytest.raises(WireRangeError):
        clip_limit(n_workers=128, bits=8)
    # the codec surfaces the same guard at trace/build time
    with pytest.raises(WireRangeError):
        PackedInt(bits=4).clip_limit(8)
    with pytest.raises(WireRangeError):
        DenseInt(bits=8).encode(
            jnp.ones((4,)), jnp.float32(1.0), jax.random.PRNGKey(0),
            n_workers=256,
        )
    # non-degenerate boundary still fine: 127 workers -> lim 1
    assert clip_limit(n_workers=127, bits=8) == 1


def test_int32_wire_still_wide_enough_for_big_fleets():
    assert clip_limit(n_workers=4096, bits=32) >= 2**18


# ---------------------------------------------------------------------------
# codec plumbing
# ---------------------------------------------------------------------------
def test_make_wire_format_registry():
    assert isinstance(make_wire_format("dense8"), DenseInt)
    assert isinstance(make_wire_format("packed4"), PackedInt)
    lg = make_wire_format("logged:packed8")
    assert isinstance(lg, Logged) and isinstance(lg.inner, PackedInt)
    wf = PackedInt(bits=16)
    assert make_wire_format(wf) is wf
    with pytest.raises(ValueError, match="unknown wire format"):
        make_wire_format("packed3")
    with pytest.raises(ValueError, match="bits"):
        PackedInt(bits=5)


def test_psum_wire_words_rejects_floats():
    """The floatless-wire contract is structural: a float leaf on the
    gradient wire is a TypeError, not a silent fallback."""
    def body(v):
        return coll.psum_wire_words(v, (AXIS,))

    with pytest.raises(TypeError, match="integer"):
        coll.vmap_workers(body, in_axes=0)(jnp.ones((N, 8), jnp.float32))
    out = coll.vmap_workers(body, in_axes=0)(jnp.ones((N, 8), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out[0]), np.full((8,), N))


def test_logged_wrapper_meters_exact_bytes():
    wf = Logged(PackedInt(bits=8))
    ints = jnp.zeros((1000,), jnp.int32)
    words = wf.pack(ints, n_workers=N)
    wf.unpack(words, (1000,), n_summed=N)
    rep = wf.report()
    assert rep["pack_bytes"] == 4 * 250 == wf.wire_bytes(1000)
    assert rep["unpack_bytes"] == 4 * 250
    assert rep["calls"][("pack", (1000,))] == 1


# ---------------------------------------------------------------------------
# compressor-level codec parity (the vmap n-worker simulation)
# ---------------------------------------------------------------------------
def _aggregate(comp, grads, state=()):
    def worker(g):
        ghat, _, m = comp.aggregate(
            state, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )
        return ghat, m

    return coll.vmap_workers(worker, in_axes=0)(grads)


@pytest.mark.parametrize("bits", [4, 8])
def test_intsgd_packed_matches_dense_bitexact(bits):
    """IntSGD over the packed wire decodes to the bit-identical ĝ as over
    dense lanes: the §5.1 clip is shared, the transport is lossless."""
    from repro.core.compressor import IntSGD
    from repro.core.scaling import AlphaState

    grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (N, 301))}
    state = AlphaState(r=jnp.full((N,), 1e-2), step=jnp.ones((N,), jnp.int32))
    dense = IntSGD(bits=bits)
    packed = IntSGD(bits=bits, wire=PackedInt(bits=bits))

    def run(comp):
        def worker(s, g):
            ghat, _, m = comp.aggregate(
                s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
            )
            return ghat, m

        return coll.vmap_workers(worker, in_axes=(0, 0))(state, grads)

    gd, md = run(dense)
    gp, mp = run(packed)
    np.testing.assert_array_equal(np.asarray(gd["w"]), np.asarray(gp["w"]))
    # identical wire-width metrics, fewer transport bytes
    np.testing.assert_array_equal(np.asarray(md.max_int), np.asarray(mp.max_int))
    assert mp.payload_bytes < md.payload_bytes or bits == 8


def test_qsgd_wire_codec_matches_two_lane_transport():
    """QSGD over a codec wire (packed signed levels) decodes the identical
    estimate as the paper's (levels, signs) two-lane gather, at half the
    gathered integer bytes."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(4), (N, 140))}
    g_lanes, m_lanes = _aggregate(make_compressor("qsgd"), grads)
    g_wire, m_wire = _aggregate(make_compressor("qsgd", wire="packed8"), grads)
    np.testing.assert_allclose(
        np.asarray(g_lanes["w"]), np.asarray(g_wire["w"]), rtol=1e-6, atol=1e-7
    )
    assert m_wire.payload_bytes < m_lanes.payload_bytes


def test_heuristic_intsgd_packed_wire():
    """HeuristicIntSGD over the packed wire: the profiling α bounds values
    inside the §5.1 clip, so tightening to the sum-clip is (near-)lossless."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(5), (N, 160))}
    g_d, _ = _aggregate(make_compressor("heuristic_intsgd"), grads)
    g_p, _ = _aggregate(
        make_compressor("heuristic_intsgd", wire="packed8"), grads
    )
    np.testing.assert_allclose(
        np.asarray(g_d["w"]), np.asarray(g_p["w"]), rtol=1e-5, atol=1e-4
    )


def test_with_wire_rejects_float_compressors():
    from repro.core import with_wire

    with pytest.raises(ValueError, match="wire-codec seam"):
        with_wire(make_compressor("powersgd"), "packed8")
