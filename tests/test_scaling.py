"""Scaling rules: Assumption 1 identities (Props 2-4) + §4.2 bit bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scaling import (
    AlphaBlockwise,
    AlphaHeuristic,
    AlphaLastStep,
    AlphaMovingAvg,
)
from repro.core.stats import DxStats, local_dx_stats, scale_dx_stats
from repro.optim import sgd


def _dx(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, shapes))}


def test_prop2_assumption1_identity():
    """Prop 2: d·η²/α_k² == η²ε² + 2n·r_k with r_k the moving average."""
    rule = AlphaMovingAvg(beta=0.9, eps=1e-4)
    key = jax.random.PRNGKey(0)
    tree = _dx(key, [(32, 16), (100,)])
    d = 32 * 16 + 100
    n, eta = 8, 0.05
    state = rule.init(tree)
    r_manual = 0.0
    for k in range(5):
        dx = _dx(jax.random.fold_in(key, k), [(32, 16), (100,)])
        stats = local_dx_stats(dx)
        state = rule.update(state, stats)
        r_manual = 0.9 * r_manual + 0.1 * float(stats.sq)
        alpha = float(rule.alpha(state, jnp.float32(eta), n, d))
        lhs = d * eta**2 / alpha**2
        rhs = eta**2 * rule.eps**2 + 2 * n * r_manual
        assert abs(lhs - rhs) / rhs < 1e-4


def test_prop3_last_step_identity():
    """Prop 3: α = η√d/(√(2n)||Δx||)  =>  d·η²/α² == 2n||Δx||²."""
    rule = AlphaLastStep()
    key = jax.random.PRNGKey(1)
    dx = _dx(key, [(64,)])
    d, n, eta = 64, 4, 0.1
    state = rule.update(rule.init(dx), local_dx_stats(dx))
    alpha = float(rule.alpha(state, jnp.float32(eta), n, d))
    sq = float(local_dx_stats(dx).sq)
    assert abs(d * eta**2 / alpha**2 - 2 * n * sq) / (2 * n * sq) < 1e-4


def test_prop4_blockwise_identity():
    """Prop 4: Σ_l d_l η²/α_l² == 2n Σ_l r_l (+ ε-term)."""
    rule = AlphaBlockwise(beta=0.0, eps=0.0)
    key = jax.random.PRNGKey(2)
    dx = _dx(key, [(32, 16), (100,)])
    dims = {"p0": 512.0, "p1": 100.0}
    d = 612.0
    n, eta = 8, 0.05
    state = rule.update(rule.init(dx), local_dx_stats(dx))
    alphas = rule.alpha_tree(state, jnp.float32(eta), n, dims, d)
    lhs = sum(
        float(dims[k]) * eta**2 / float(alphas[k]) ** 2 for k in dims
    )
    rhs = 2 * n * float(local_dx_stats(dx).sq)
    assert abs(lhs - rhs) / rhs < 1e-4


def test_section42_bits_bound():
    """§4.2: with α = √d/(√(2n)||g||), ||α g||∞ <= √d/√(2n) so the wire
    needs at most 1 + log2(√d/√(2n)) bits per coordinate."""
    key = jax.random.PRNGKey(3)
    d, n = 10000, 100
    g = jax.random.normal(key, (d,))
    alpha = jnp.sqrt(d / (2.0 * n)) / jnp.linalg.norm(g)
    maxint = float(jnp.max(jnp.abs(alpha * g)))
    bound = np.sqrt(d / (2.0 * n))
    assert maxint <= bound + 1e-5
    bits = 1 + np.log2(max(maxint, 1))
    assert bits <= 1 + np.log2(bound)


def test_momentum_alpha_pinned():
    """§4.1 momentum correction, regression-pinned by hand: with heavy-ball
    μ the α rule must see the APPLIED update rescaled to gradient-equivalent
    units, (1-μ)²||Δx||². For μ=0.9, β=0.9, one observed update with
    ||Δx||²=2, d=100, n=4, η=0.5:

        s  = (1-0.9)² · 2     = 0.02
        r  = 0.9·0 + 0.1·s    = 0.002
        α  = √100 / √(2·4·0.002/0.25 + (1e-8)²) = 10/√0.064 = 39.528471
    """
    opt = sgd(momentum=0.9)
    assert abs(opt.dx_scale - 0.1) < 1e-12
    rule = AlphaMovingAvg()  # β=0.9, ε=1e-8 (paper defaults)
    dx = {"x": jnp.sqrt(jnp.full((1,), 2.0))}
    stats = scale_dx_stats(local_dx_stats(dx), opt.dx_scale)
    assert abs(float(stats.sq) - 0.02) < 1e-8
    state = rule.update(rule.init(dx), stats)
    alpha = float(rule.alpha(state, jnp.float32(0.5), 4, 100))
    np.testing.assert_allclose(alpha, 39.528471, rtol=1e-5)
    # momentum-free optimizers are untouched (dx_scale == 1)
    assert sgd().dx_scale == 1.0


def test_heuristic_alpha_no_overflow():
    """Sapio rule keeps every scaled coordinate within the int range."""
    rule = AlphaHeuristic(bits=8)
    key = jax.random.PRNGKey(4)
    g = jax.random.normal(key, (1000,)) * 37.0
    absmax = jnp.max(jnp.abs(g))
    alpha = rule.alpha_from_absmax(absmax, n_workers=16)
    assert float(jnp.max(jnp.abs(alpha * g))) * 16 <= 2**7 - 1 + 1e-3
