"""Checkpoint store: atomicity, keep-k GC, async writes, restore paths."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore


def _tree(key, scale=1.0):
    return {
        "params": {"w": jax.random.normal(key, (8, 4)) * scale, "b": jnp.ones((4,))},
        "step_scalar": jnp.float32(scale),
    }


def test_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), async_writes=False)
    tree = _tree(jax.random.PRNGKey(0))
    store.save(5, tree, extra={"loss": 1.25})
    got, extra, step = store.restore(tree)
    assert step == 5 and extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2, async_writes=False)
    for s in [1, 2, 3, 4]:
        store.save(s, _tree(jax.random.PRNGKey(s), scale=s))
    assert store.all_steps() == [3, 4]
    got, _, step = store.restore(_tree(jax.random.PRNGKey(0)))
    assert step == 4
    assert float(got["step_scalar"]) == 4.0


def test_async_writer(tmp_path):
    store = CheckpointStore(str(tmp_path), async_writes=True)
    for s in range(3):
        store.save(s, _tree(jax.random.PRNGKey(s), scale=s))
    store.wait()
    assert store.latest_step() == 2


def test_no_tmp_dirs_visible_after_publish(tmp_path):
    store = CheckpointStore(str(tmp_path), async_writes=False)
    store.save(1, _tree(jax.random.PRNGKey(0)))
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)


def test_structure_mismatch_rejected(tmp_path):
    store = CheckpointStore(str(tmp_path), async_writes=False)
    store.save(1, _tree(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError):
        store.restore({"different": jnp.zeros((3,))})


def test_shape_mismatch_rejected(tmp_path):
    store = CheckpointStore(str(tmp_path), async_writes=False)
    tree = _tree(jax.random.PRNGKey(0))
    store.save(1, tree)
    bad = jax.tree.map(lambda x: jnp.zeros((7,) + x.shape, x.dtype), tree)
    with pytest.raises(ValueError):
        store.restore(bad)


def test_restore_latest_of_many(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=10, async_writes=False)
    for s in [10, 20, 30]:
        store.save(s, _tree(jax.random.PRNGKey(s), scale=float(s)))
    got, _, step = store.restore(_tree(jax.random.PRNGKey(0)), step=20)
    assert step == 20 and float(got["step_scalar"]) == 20.0
