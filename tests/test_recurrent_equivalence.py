"""Chunked-parallel training paths must EXACTLY match step-by-step decode —
the invariant that guarantees serve-time outputs agree with train-time
likelihoods for the recurrent families (Mamba2 SSD, mLSTM GLA-form, sLSTM),
and that the GQA KV-cache decode agrees with full attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import ssm, xlstm
from repro.models.common import Axes, plan_heads

AXES = Axes()
B, T, D = 2, 32, 24
H, P, N = 2, 8, 16


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (B, T, D)) * 0.5


def _decode_all(step_fn, cache):
    ys = []
    for t in range(T):
        y_t, cache = step_fn(t, cache)
        ys.append(y_t)
    return jnp.concatenate(ys, axis=1)


def test_mamba2_train_equals_decode(x):
    p = ssm.init_mamba2_params(jax.random.PRNGKey(0), D, H, P, N)
    kw = dict(n_heads_local=H, head_dim=P, d_state=N)
    y_train = ssm.mamba2_train(p, x, AXES, chunk=8, **kw)
    y_dec = _decode_all(
        lambda t, c: ssm.mamba2_decode(p, x[:, t : t + 1], c, AXES, **kw),
        ssm.init_mamba2_cache(B, H, P, N),
    )
    np.testing.assert_allclose(y_train, y_dec, atol=5e-5, rtol=1e-4)


def test_mlstm_train_equals_decode(x):
    p = xlstm.init_mlstm_params(jax.random.PRNGKey(0), D, H, P)
    kw = dict(n_heads_local=H, head_dim=P)
    y_train = xlstm.mlstm_train(p, x, AXES, chunk=8, **kw)
    y_dec = _decode_all(
        lambda t, c: xlstm.mlstm_decode(p, x[:, t : t + 1], c, AXES, **kw),
        xlstm.init_mlstm_cache(B, H, P),
    )
    np.testing.assert_allclose(y_train, y_dec, atol=5e-5, rtol=1e-4)


def test_slstm_train_equals_decode(x):
    p = xlstm.init_slstm_params(jax.random.PRNGKey(0), D, H, P)
    kw = dict(n_heads_local=H, head_dim=P)
    y_train = xlstm.slstm_train(p, x, AXES, **kw)
    y_dec = _decode_all(
        lambda t, c: xlstm.slstm_decode(p, x[:, t : t + 1], c, AXES, **kw),
        xlstm.init_slstm_cache(B, H, P),
    )
    np.testing.assert_allclose(y_train, y_dec, atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [None, 8])
def test_attention_train_equals_kv_decode(x, window):
    """attention_train's chunked online softmax at each position must match
    decoding that position against a KV cache filled with the prefix."""
    layout = plan_heads(4, 2, 8, 1)
    p = attn.init_attn_params(jax.random.PRNGKey(0), D, layout)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    y_train = attn.attention_train(p, x, pos, AXES, layout, window=window, chunk=8)
    cache = attn.init_cache(B, T, layout, jnp.float32)
    ys = []
    for t in range(T):
        y_t, cache = attn.attention_decode(
            p, x[:, t : t + 1], jnp.full((B,), t, jnp.int32), cache, AXES,
            layout, window=window,
        )
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_train), np.asarray(y_dec), atol=1e-4, rtol=1e-3
    )
