"""The version-portable collectives layer: shim resolution, the single-
resolution-point invariant, and axis primitives under vmap simulation."""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import collectives as coll

SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)


def test_single_resolution_point():
    """Exactly one module in src/ touches the raw shard_map API (the shim).
    This is the narrow regex ancestor of linter rule C001
    (repro.analysis.lint), which generalizes it to EVERY raw lax collective
    surface — kept as a fast standalone regression for the shard_map case."""
    pat = re.compile(r"jax\.shard_map|experimental[. ]shard_map")
    offenders = []
    for root, _, files in os.walk(SRC):
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            with open(path) as fh:
                if pat.search(fh.read()):
                    offenders.append(os.path.relpath(path, SRC))
    allowed = {
        os.path.join("repro", "parallel", "collectives.py"),  # the shim
        # the C001 linter names the banned module paths as string data
        os.path.join("repro", "analysis", "lint.py"),
    }
    assert set(offenders) <= allowed, offenders
    assert os.path.join("repro", "parallel", "collectives.py") in offenders


def test_shim_resolves_and_runs():
    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        return coll.psum_tree(x, ("data",))

    out = jax.jit(
        coll.shard_map(
            f, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False
        )
    )(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


def test_sharded_jit_pipeline():
    mesh = jax.make_mesh((1,), ("data",))
    fn = coll.sharded_jit(
        lambda x: x * 2.0, mesh, (P(),), P()
    )
    np.testing.assert_allclose(np.asarray(fn(jnp.ones(3))), 2 * np.ones(3))


def test_axis_primitives_under_vmap():
    """The primitives lower identically under vmap(axis_name=...) — the
    single-device simulation contract simulate.py relies on."""
    n = 4
    xs = jnp.arange(float(n))

    def worker(x):
        s = coll.psum_tree(x, (coll.WORKER_AXIS,))
        m = coll.pmax_tree(x, (coll.WORKER_AXIS,))
        g = coll.all_gather_flat(x, (coll.WORKER_AXIS,), n)
        idx = coll.linear_axis_index((coll.WORKER_AXIS,), (n,))
        return s, m, g, idx

    s, m, g, idx = coll.vmap_workers(worker, in_axes=0)(xs)
    np.testing.assert_allclose(np.asarray(s), np.full(n, 6.0))
    np.testing.assert_allclose(np.asarray(m), np.full(n, 3.0))
    # every worker sees the same flat gather, ordered by linear index
    for w in range(n):
        np.testing.assert_allclose(np.asarray(g[w]), np.arange(float(n)))
    np.testing.assert_array_equal(np.asarray(idx), np.arange(n))


def test_mesh_helpers():
    mesh = coll.mesh_from_counts(data=1, model=1)
    assert coll.dp_axes_of(mesh) == ("data",)
    assert coll.dp_sizes_of(mesh) == (1,)
    assert coll.axis_spec(("data",)) == "data"
    assert coll.axis_spec(("pod", "data")) == ("pod", "data")
