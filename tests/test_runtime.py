"""Fault tolerance: elastic re-mesh planning + straggler-tolerant sums +
end-to-end failure/recovery with checkpoint restore and worker-count change
(IntSGD's α adapts because n is an input)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.simulate import SimTrainer
from repro.checkpoint import CheckpointStore
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.runtime import plan_after_failures, straggler_tolerant_sum
from repro.runtime.straggler import decode_partial


def test_elastic_plan_retires_whole_tp_groups():
    plan = plan_after_failures(dp=16, tp=16, failed_devices=[5, 250], global_batch=256)
    # device 5 -> replica 0; device 250 -> replica 15
    assert plan.retired_replicas == (0, 15)
    assert plan.n_dp == 14
    assert plan.global_batch == 256  # keep_global_batch default


def test_elastic_plan_rescaled_batch():
    plan = plan_after_failures(
        dp=8, tp=2, failed_devices=[3], global_batch=64, keep_global_batch=False
    )
    assert plan.n_dp == 7
    assert plan.global_batch == 56


def test_elastic_plan_total_failure():
    with pytest.raises(RuntimeError):
        plan_after_failures(dp=2, tp=2, failed_devices=[0, 3], global_batch=8)


def test_straggler_tolerant_sum():
    """Dropping a straggler = sum over alive + divide by n_live; exact."""
    n = 4
    ctx = CommCtx(axes=("w",), axis_sizes=(n,))
    ints = jnp.arange(n * 6, dtype=jnp.int32).reshape(n, 6)
    alive = jnp.array([True, True, False, True])

    def worker(x, a):
        s, n_live = straggler_tolerant_sum({"g": x}, a, ctx)
        return s["g"], n_live

    s, n_live = jax.vmap(worker, axis_name="w")(ints, alive)
    expect = np.asarray(ints)[np.asarray(alive)].sum(0)
    np.testing.assert_array_equal(np.asarray(s[0]), expect)
    assert int(n_live[0]) == 3
    ghat = decode_partial({"g": s[0]}, jnp.float32(2.0), n_live[0])
    np.testing.assert_allclose(np.asarray(ghat["g"]), expect / (3 * 2.0), rtol=1e-6)


def test_failure_recovery_end_to_end(tmp_path):
    """Train with n=8, checkpoint, 'lose' 2 workers, resume with n=6 —
    training continues to converge (α recomputed with the new n)."""
    prob = make_logreg(jax.random.PRNGKey(0), n_workers=8, m=32, d=20)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(20)}
    store = CheckpointStore(str(tmp_path), async_writes=False)

    tr8 = SimTrainer(prob.worker_loss, 8, make_compressor("intsgd"), sgd(), constant(0.5))
    st = tr8.init(x0)
    for i in range(40):
        st, _ = tr8.step(st, data)
    store.save(40, {"params": st.params})
    loss_at_ckpt = float(prob.full_loss(st.params["x"]))

    # failure: replicas 6,7 die -> resume with 6 workers and their data
    got, _, step = store.restore({"params": x0})
    tr6 = SimTrainer(prob.worker_loss, 6, make_compressor("intsgd"), sgd(), constant(0.5))
    st6 = tr6.init(got["params"])
    data6 = jax.tree.map(lambda x: x[:6], data)
    for i in range(60):
        st6, _ = tr6.step(st6, data6)
    # objective over the surviving shards keeps decreasing
    surv = jax.tree.map(lambda x: x[:6], data)
    surv_loss = lambda x: float(
        jnp.mean(jax.nn.softplus(-(jnp.einsum("wmd,d->wm", surv["A"], x) * surv["b"])))
    )
    assert surv_loss(st6.params["x"]) < surv_loss(got["params"]["x"]) + 1e-6
