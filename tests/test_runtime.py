"""Fault tolerance: elastic re-mesh planning + straggler-tolerant sums over
the wire codec + end-to-end failure/recovery with checkpoint restore and
worker-count change (IntSGD's α adapts because n is an input)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced_mesh
from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.simulate import SimTrainer
from repro.checkpoint import CheckpointStore
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.parallel import collectives as coll
from repro.runtime import plan_after_failures, straggler_tolerant_sum
from repro.runtime.straggler import decode_partial
from repro.wire import DenseInt, PackedInt, WireRangeError


def test_elastic_plan_retires_whole_tp_groups():
    plan = plan_after_failures(dp=16, tp=16, failed_devices=[5, 250], global_batch=256)
    # device 5 -> replica 0; device 250 -> replica 15
    assert plan.retired_replicas == (0, 15)
    assert plan.n_dp == 14
    assert plan.global_batch == 256  # keep_global_batch default


def test_elastic_plan_rescaled_batch():
    plan = plan_after_failures(
        dp=8, tp=2, failed_devices=[3], global_batch=64, keep_global_batch=False
    )
    assert plan.n_dp == 7
    assert plan.global_batch == 56


def test_elastic_plan_total_failure():
    with pytest.raises(RuntimeError):
        plan_after_failures(dp=2, tp=2, failed_devices=[0, 3], global_batch=8)


def test_elastic_plan_validates_wire_codec():
    """Re-meshing must re-validate the wire codec for the NEW worker count
    at PLAN time: int8's clip limit (2^7-1)//n degenerates to 0 at n>=128,
    which previously only surfaced as a WireRangeError deep at trace time
    inside the rebuilt step."""
    # valid: surviving count stays representable; the note surfaces the lim
    plan = plan_after_failures(
        dp=16, tp=1, failed_devices=[5], global_batch=256, wire="packed8"
    )
    assert plan.n_dp == 15
    assert "packed8" in plan.note and "revalidated" in plan.note
    assert "clip limit 7->8" in plan.note
    # invalid: 130 replicas minus 2 leaves 128 — int8 cannot carry that sum
    with pytest.raises(WireRangeError):
        plan_after_failures(
            dp=130, tp=1, failed_devices=[0, 1], global_batch=256,
            wire="packed8",
        )
    # the microbatch-pipelined step clips for n_dp x M — the plan must
    # validate THAT product (32 workers alone fit int8; x8 microbatches not)
    with pytest.raises(WireRangeError):
        plan_after_failures(
            dp=33, tp=1, failed_devices=[0], global_batch=256,
            wire="packed8", microbatches=8,
        )
    plan_mb = plan_after_failures(
        dp=33, tp=1, failed_devices=[0], global_batch=256,
        wire="packed8", microbatches=2,
    )
    assert "x2 microbatches" in plan_mb.note
    # no codec given -> behavior unchanged
    plan2 = plan_after_failures(
        dp=130, tp=1, failed_devices=[0, 1], global_batch=256
    )
    assert plan2.n_dp == 128


def test_straggler_tolerant_sum():
    """Dropping a straggler = sum over alive + divide by n_live; exact."""
    n = 4
    ctx = CommCtx(axes=("w",), axis_sizes=(n,))
    ints = jnp.arange(n * 6, dtype=jnp.int32).reshape(n, 6)
    alive = jnp.array([True, True, False, True])

    def worker(x, a):
        s, n_live = straggler_tolerant_sum({"g": x}, a, ctx)
        return s["g"], n_live

    s, n_live = jax.vmap(worker, axis_name="w")(ints, alive)
    expect = np.asarray(ints)[np.asarray(alive)].sum(0)
    np.testing.assert_array_equal(np.asarray(s[0]), expect)
    assert int(n_live[0]) == 3
    ghat, all_dead = decode_partial({"g": s[0]}, jnp.float32(2.0), n_live[0])
    np.testing.assert_allclose(np.asarray(ghat["g"]), expect / (3 * 2.0), rtol=1e-6)
    assert not bool(all_dead)


@pytest.mark.parametrize("wf", [DenseInt(bits=8), PackedInt(bits=8)],
                         ids=["dense8", "packed8"])
def test_straggler_masked_contribution_is_exactly_zero(wf):
    """A dead worker contributes EXACTLY zero post-unpack, whatever garbage
    its integer image held — for PackedInt this is the guard-bit bias
    correction (its wire word is the pure bias pattern, subtracted by
    unpack's n_summed=n accounting), not a lucky zero."""
    n = 4
    ctx = CommCtx(axes=(coll.WORKER_AXIS,), axis_sizes=(n,))
    lim = wf.clip_limit(n)
    key = jax.random.PRNGKey(7)
    ints = jax.random.randint(key, (n, 257), -lim, lim + 1)
    alive = jnp.array([True, True, False, True])

    def run(payload):
        def worker(x, a):
            s, n_live = straggler_tolerant_sum({"g": x}, a, ctx, wf)
            return s["g"], n_live

        return coll.vmap_workers(worker, in_axes=(0, 0))(payload, alive)

    s, n_live = run(ints)
    expect = np.asarray(ints)[np.asarray(alive)].sum(0)
    np.testing.assert_array_equal(np.asarray(s[0]), expect)
    assert int(n_live[0]) == 3
    # property: replacing the dead worker's payload with anything in range
    # changes NOTHING on the decoded side
    garbage = ints.at[2].set(
        jax.random.randint(jax.random.fold_in(key, 1), (257,), -lim, lim + 1)
    )
    s2, _ = run(garbage)
    np.testing.assert_array_equal(np.asarray(s2[0]), expect)


def test_straggler_dense_packed_parity():
    """dense8 and packed8 agree bit-exactly on the partial sum (shared §5.1
    integer image; only the transport words differ)."""
    n = 4
    ctx = CommCtx(axes=(coll.WORKER_AXIS,), axis_sizes=(n,))
    lim = PackedInt(bits=8).clip_limit(n)
    ints = jax.random.randint(jax.random.PRNGKey(3), (n, 301), -lim, lim + 1)
    alive = jnp.array([True, False, True, True])

    def run(wf):
        def worker(x, a):
            s, n_live = straggler_tolerant_sum({"g": x}, a, ctx, wf)
            return s["g"], n_live

        return coll.vmap_workers(worker, in_axes=(0, 0))(ints, alive)

    s_d, nl_d = run(DenseInt(bits=8))
    s_p, nl_p = run(PackedInt(bits=8))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_p))
    np.testing.assert_array_equal(np.asarray(nl_d), np.asarray(nl_p))


def test_decode_partial_alpha_tree_and_all_dead_flag():
    """decode_partial takes IntSGD's per-leaf α tree (Algorithm 2) and flags
    the all-workers-dead round instead of silently decoding zeros."""
    int_sum = {"a": jnp.array([6, -4], jnp.int32), "b": jnp.array([9], jnp.int32)}
    alphas = {"a": jnp.float32(2.0), "b": jnp.float32(3.0)}
    ghat, all_dead = decode_partial(int_sum, alphas, jnp.int32(3))
    np.testing.assert_allclose(np.asarray(ghat["a"]), [1.0, -2.0 / 3.0], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ghat["b"]), [1.0], rtol=1e-6)
    assert not bool(all_dead)
    # scalar α still broadcasts
    ghat_s, _ = decode_partial(int_sum, jnp.float32(2.0), jnp.int32(3))
    np.testing.assert_allclose(np.asarray(ghat_s["b"]), [1.5], rtol=1e-6)
    # n_live == 0: finite output, loud flag
    ghat0, dead0 = decode_partial(int_sum, alphas, jnp.int32(0))
    assert bool(dead0)
    assert np.all(np.isfinite(np.asarray(ghat0["a"])))


@pytest.mark.slow
def test_straggler_mesh_packed8():
    """Straggler sum over the REAL 4-device mesh: packed8 and dense8 wires
    agree bit-exactly with one dead worker, and the decode matches numpy."""
    out = run_forced_mesh(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.comm import CommCtx
from repro.parallel.collectives import shard_map
from repro.runtime.straggler import straggler_tolerant_sum, decode_partial
from repro.wire import DenseInt, PackedInt

n = 4
mesh = jax.make_mesh((n,), ("data",))
ctx = CommCtx(axes=("data",), axis_sizes=(n,))
lim = PackedInt(bits=8).clip_limit(n)
key = jax.random.PRNGKey(0)
ints = {"w": jax.random.randint(key, (n, 300), -lim, lim + 1),
        "b": jax.random.randint(jax.random.fold_in(key, 1), (n, 7), -lim, lim + 1)}
alive = jnp.array([True, True, False, True])

def run(wf):
    def body(t, a):
        t1 = jax.tree.map(lambda v: v[0], t)
        s, n_live = straggler_tolerant_sum(t1, a[0], ctx, wf)
        return s, n_live
    f = jax.jit(shard_map(body, mesh=mesh,
        in_specs=({"w": P("data"), "b": P("data")}, P("data")),
        out_specs=({"w": P(), "b": P()}, P()), check_vma=False))
    return f(ints, alive)

s_p, nl = run(PackedInt(bits=8))
s_d, _ = run(DenseInt(bits=8))
mask = np.asarray(alive)
for k in ints:
    expect = np.asarray(ints[k])[mask].sum(0)
    np.testing.assert_array_equal(np.asarray(s_p[k]), expect)
    np.testing.assert_array_equal(np.asarray(s_d[k]), np.asarray(s_p[k]))
assert int(nl) == 3
alphas = {"w": jnp.float32(2.0), "b": jnp.float32(4.0)}
ghat, all_dead = decode_partial(s_p, alphas, nl)
np.testing.assert_allclose(np.asarray(ghat["w"]),
    np.asarray(ints["w"])[mask].sum(0) / (3 * 2.0), rtol=1e-6)
assert not bool(all_dead)
print("STRAGGLER_MESH_OK")
"""
    )
    assert "STRAGGLER_MESH_OK" in out


def test_failure_recovery_end_to_end(tmp_path):
    """Train with n=8, checkpoint, 'lose' 2 workers, resume with n=6 —
    training continues to converge (α recomputed with the new n)."""
    prob = make_logreg(jax.random.PRNGKey(0), n_workers=8, m=32, d=20)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(20)}
    store = CheckpointStore(str(tmp_path), async_writes=False)

    tr8 = SimTrainer(prob.worker_loss, 8, make_compressor("intsgd"), sgd(), constant(0.5))
    st = tr8.init(x0)
    for i in range(40):
        st, _ = tr8.step(st, data)
    store.save(40, {"params": st.params})
    loss_at_ckpt = float(prob.full_loss(st.params["x"]))

    # failure: replicas 6,7 die -> resume with 6 workers and their data
    got, _, step = store.restore({"params": x0})
    tr6 = SimTrainer(prob.worker_loss, 6, make_compressor("intsgd"), sgd(), constant(0.5))
    st6 = tr6.init(got["params"])
    data6 = jax.tree.map(lambda x: x[:6], data)
    for i in range(60):
        st6, _ = tr6.step(st6, data6)
    # objective over the surviving shards keeps decreasing
    surv = jax.tree.map(lambda x: x[:6], data)
    surv_loss = lambda x: float(
        jnp.mean(jax.nn.softplus(-(jnp.einsum("wmd,d->wm", surv["A"], x) * surv["b"])))
    )
    assert surv_loss(st6.params["x"]) < surv_loss(got["params"]["x"]) + 1e-6
