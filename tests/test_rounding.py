"""Property tests for the Int operator (paper §2, Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import rounding

finite_floats = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32
)


@given(st.lists(finite_floats, min_size=1, max_size=64), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_int_rounding_bounded_error(vals, seed):
    """|Int(t) - t| < 1 always (the rounding moves to an adjacent integer)."""
    x = jnp.asarray(vals, jnp.float32)
    r = rounding.stochastic_round(x, jax.random.PRNGKey(seed))
    assert np.all(np.abs(np.asarray(r) - np.asarray(x)) < 1.0 + 1e-5)
    # result is integral
    assert np.all(np.asarray(r) == np.round(np.asarray(r)))


@given(finite_floats)
@settings(max_examples=30, deadline=None)
def test_int_rounding_unbiased(t):
    """E[Int(t)] = t (Lemma 1, eq. 3) — Monte Carlo with tight CI."""
    n = 4000
    x = jnp.full((n,), t, jnp.float32)
    keys = jax.random.PRNGKey(0)
    r = rounding.stochastic_round(x, keys)
    frac = float(t - np.floor(t))
    se = np.sqrt(max(frac * (1 - frac), 1e-12) / n)
    assert abs(float(jnp.mean(r)) - t) <= max(6 * se, 1e-3 * max(abs(t), 1.0))


def test_int_rounding_variance_bound():
    """E[(Int(t)-t)^2] <= 1/4 (Lemma 1, eq. 4), worst case at frac=0.5."""
    key = jax.random.PRNGKey(0)
    for frac in [0.1, 0.25, 0.5, 0.75, 0.9]:
        x = jnp.full((20000,), 3.0 + frac, jnp.float32)
        r = rounding.stochastic_round(x, key)
        var = float(jnp.mean(jnp.square(r - x)))
        assert var <= 0.25 + 0.02, (frac, var)
        # exact Bernoulli variance: frac*(1-frac)
        assert abs(var - frac * (1 - frac)) < 0.02


def test_integer_inputs_fixed_points():
    """Integers are fixed points of Int (prob of +1 is exactly 0)."""
    x = jnp.arange(-50, 50, dtype=jnp.float32)
    r = rounding.stochastic_round(x, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(x))


def test_encode_decode_roundtrip_precision():
    """(1/α)Int(αx) -> x as α -> inf (quantization error ~ 1/α)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,))
    for alpha, tol in [(10.0, 0.1), (1000.0, 1e-3), (1e6, 1e-6)]:
        ints = rounding.encode(x, jnp.float32(alpha), key, n_workers=1, bits=32)
        back = rounding.decode(ints, jnp.float32(alpha), n_workers=1)
        assert float(jnp.max(jnp.abs(back - x))) <= tol


def test_clip_for_wire_sum_fits():
    """n-worker sum of clipped ints must fit the wire dtype (paper §5.1)."""
    for bits, n in [(8, 16), (16, 64), (32, 1000)]:
        lim = rounding._INT_RANGE[bits] // n
        ints = jnp.full((100,), 10 * lim, jnp.float32)
        clipped = rounding.clip_for_wire(ints, n_workers=n, bits=bits)
        assert float(jnp.max(jnp.abs(clipped))) * n <= rounding._INT_RANGE[bits]


def test_deterministic_round_matches_torch_semantics():
    x = jnp.asarray([0.5, 1.5, 2.5, -0.5, -1.5, 0.49, 0.51])
    r = rounding.deterministic_round(x)
    np.testing.assert_array_equal(
        np.asarray(r), np.asarray([0.0, 2.0, 2.0, -0.0, -2.0, 0.0, 1.0])
    )
