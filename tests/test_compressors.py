"""Compressor contracts under the n-worker vmap simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.compressor import aggregate_exact

N = 4
AXIS = "workers"
CTX = CommCtx(axes=(AXIS,), axis_sizes=(N,))


def _run_round(comp, grads_per_worker, key=None, eta=0.1):
    key = key if key is not None else jax.random.PRNGKey(0)
    state = comp.init(jax.tree.map(lambda x: x[0], grads_per_worker))
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)

    def worker(s, g):
        return comp.aggregate(s, g, key=key, eta=jnp.float32(eta), ctx=CTX)

    ghat, new_state, metrics = jax.vmap(
        worker, in_axes=(0, 0), axis_name=AXIS
    )(state, grads_per_worker)
    return jax.tree.map(lambda x: x[0], ghat), new_state, metrics


def _grads(key, shape=(64,)):
    return {"w": jax.random.normal(key, (N,) + shape)}


@pytest.mark.parametrize(
    "name", ["none", "intsgd", "intsgd_determ", "intsgd_block", "intsgd8",
             "heuristic_intsgd", "qsgd", "natsgd", "powersgd", "signsgd",
             "topk", "intdiana", "allgather_sgd"],
)
def test_aggregate_identical_across_workers(name):
    """The decoded estimate must be IDENTICAL on every worker (the property
    that lets all workers apply the same update without a broadcast)."""
    comp = make_compressor(name)
    grads = _grads(jax.random.PRNGKey(1))
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)

    def worker(s, g):
        g_, s_, m = comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    ghat_all = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    for i in range(1, N):
        np.testing.assert_allclose(
            ghat_all["w"][0], ghat_all["w"][i], rtol=1e-6, atol=1e-7
        )


@pytest.mark.parametrize("name", ["intsgd", "qsgd", "natsgd"])
def test_unbiased_compressors(name):
    """E[ghat] == mean(grads) for the unbiased families (MC over keys).

    IntSGD needs a warmed α state (r_k > 0): with r=0 (the k=0 state) α is
    degenerate, which is exactly why the paper makes the first communication
    exact — asserted separately in test_intsgd_step0_state_is_degenerate."""
    from repro.core.scaling import AlphaState

    comp = make_compressor(name)
    grads = _grads(jax.random.PRNGKey(2), (32,))
    target = np.asarray(jnp.mean(grads["w"], axis=0))

    state0 = comp.init({"w": grads["w"][0]})
    state0 = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state0)
    if name == "intsgd":
        state0 = AlphaState(r=jnp.full((N,), 1e-2), step=jnp.ones((N,), jnp.int32))

    def worker(s, g, key):
        g_, _, _ = comp.aggregate(
            s, g, key=key, eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    acc = np.zeros(32)
    trials = 300
    for t in range(trials):
        ghat = jax.vmap(worker, in_axes=(0, 0, None), axis_name=AXIS)(
            state0, grads, jax.random.PRNGKey(100 + t)
        )
        acc += np.asarray(ghat["w"][0])
    err = np.abs(acc / trials - target).max()
    assert err < 0.05, (name, err)


def test_intsgd_step0_state_is_degenerate():
    """With the k=0 state (r=0) the decoded aggregate is badly biased —
    the reason Algorithm 1 makes the first communication exact."""
    comp = make_compressor("intsgd")
    grads = _grads(jax.random.PRNGKey(2), (32,))
    ghat, _, _ = _run_round(comp, grads)
    target = np.asarray(jnp.mean(grads["w"], axis=0))
    assert np.abs(np.asarray(ghat["w"]) - target).max() > 0.05


def test_intsgd_exact_when_alpha_huge():
    """As α→∞ quantization vanishes: IntSGD(Random) == exact mean."""
    from repro.core.compressor import IntSGD
    from repro.core.scaling import AlphaMovingAvg, AlphaState

    comp = IntSGD(alpha_rule=AlphaMovingAvg(eps=1e-12))
    grads = _grads(jax.random.PRNGKey(3), (16,))
    # state with r=0 -> alpha = sqrt(d)/eps = gigantic
    state = AlphaState(r=jnp.zeros((N,)), step=jnp.ones((N,), jnp.int32))

    def worker(s, g):
        g_, _, _ = comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    ghat = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    exact = jnp.mean(grads["w"], axis=0)
    # alpha huge -> ints clipped... bits=32 lim=2^31/4: alpha*g may exceed ->
    # this is exactly why the paper needs the first-step-exact convention;
    # here we only check the decode matches within clip-free range
    mask = np.abs(np.asarray(grads["w"])).max(0) * 1e10 < 2**31 / N
    got = np.asarray(ghat["w"][0])
    want = np.asarray(exact)
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-4, atol=1e-6)


def test_error_feedback_accumulates():
    """EF invariant: e' = (g + e) - C(g + e) for each worker independently."""
    comp = make_compressor("signsgd")
    grads = _grads(jax.random.PRNGKey(4), (32,))
    ghat, new_state, _ = _run_round(comp, grads)
    work = np.asarray(grads["w"])  # e=0 initially
    scale = np.mean(np.abs(work), axis=-1, keepdims=True)
    local_c = scale * np.sign(work)
    np.testing.assert_allclose(
        np.asarray(new_state["w"]), work - local_c, rtol=1e-5, atol=1e-6
    )


def test_intdiana_shift_tracking():
    """h_local += Q(g - h); after one round with h=0, h_local == Q(g_i)."""
    comp = make_compressor("intdiana")
    grads = _grads(jax.random.PRNGKey(5), (16,))
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)
    # make alpha well-defined: r>0
    state["alpha"] = jax.tree.map(
        lambda x: jnp.ones_like(x) if x.dtype != jnp.int32 else x, state["alpha"]
    )

    def worker(s, g):
        return comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )

    ghat, new_state, m = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    # global shift advanced by mean of quantized diffs == ghat (h started at 0)
    np.testing.assert_allclose(
        np.asarray(new_state["h_global"]["w"][0]), np.asarray(ghat["w"][0]), rtol=1e-6
    )
    # per-worker shifts differ (heterogeneous grads) — the per-worker state
    h = np.asarray(new_state["h_local"]["w"])
    assert not np.allclose(h[0], h[1])


def test_adamw_alpha_pinned():
    """§4.1 EMA correction for AdamW, regression-pinned by hand (mirrors
    tests/test_scaling.py::test_momentum_alpha_pinned, the PR 1 heavy-ball
    version): Adam's first moment m = b1·m + (1-b1)·g amplifies injected
    quantization noise by 1/(1-b1) at steady state, so the α rule must see
    the applied update rescaled by dx_scale = 1-b1 — NOT the raw
    lr-scaled, preconditioned Δx. For b1=0.9, β=0.9, one observed update
    with ||Δx||²=2, d=100, n=4, η=0.5:

        s  = (1-0.9)² · 2     = 0.02
        r  = 0.9·0 + 0.1·s    = 0.002
        α  = √100 / √(2·4·0.002/0.25 + (1e-8)²) = 10/√0.064 = 39.528471

    Without the fix (dx_scale left at 1.0) the same trajectory gives
    r = 0.2 and α = 3.9528471 — a 10× under-scaling of the wire."""
    from repro.core.scaling import AlphaMovingAvg
    from repro.core.stats import local_dx_stats, scale_dx_stats
    from repro.optim import adamw

    opt = adamw()  # b1=0.9
    assert abs(opt.dx_scale - 0.1) < 1e-12
    assert abs(adamw(b1=0.8).dx_scale - 0.2) < 1e-12
    rule = AlphaMovingAvg()  # β=0.9, ε=1e-8 (paper defaults)
    dx = {"x": jnp.sqrt(jnp.full((1,), 2.0))}
    stats = scale_dx_stats(local_dx_stats(dx), opt.dx_scale)
    assert abs(float(stats.sq) - 0.02) < 1e-8
    state = rule.update(rule.init(dx), stats)
    alpha = float(rule.alpha(state, jnp.float32(0.5), 4, 100))
    np.testing.assert_allclose(alpha, 39.528471, rtol=1e-5)
    # the buggy (uncorrected) trajectory lands 10× lower — pin the distance
    bad = rule.update(rule.init(dx), local_dx_stats(dx))
    alpha_bad = float(rule.alpha(bad, jnp.float32(0.5), 4, 100))
    np.testing.assert_allclose(alpha_bad, 3.9528471, rtol=1e-4)


def test_intdiana_aggregate_wire_matches_aggregate():
    """The wire-level split (aggregate_wire + decode/shift-advance, the
    fused-route entry) must reproduce aggregate() exactly: same ĝ, same
    h_local, and ĝ == the advanced h_global."""
    comp = make_compressor("intdiana")
    grads = _grads(jax.random.PRNGKey(6), (16,))
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)
    state["alpha"] = jax.tree.map(
        lambda x: jnp.ones_like(x) if x.dtype != jnp.int32 else x, state["alpha"]
    )
    key, eta = jax.random.PRNGKey(0), jnp.float32(0.1)

    def ref(s, g):
        return comp.aggregate(s, g, key=key, eta=eta, ctx=CTX)

    def wirelevel(s, g):
        wa, alphas, s2, m = comp.aggregate_wire(s, g, key=key, eta=eta, ctx=CTX)
        wf = comp.wire_format
        mean_q = jax.tree.map(
            lambda si, a: wf.decode(si, a, n_workers=N), wa.ints, alphas
        )
        h_global = jax.tree.map(jnp.add, s2["h_global"], mean_q)
        return h_global, comp.fused_store_shift(s2, h_global)

    g_ref, s_ref, _ = jax.vmap(ref, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    g_wire, s_wire = jax.vmap(wirelevel, in_axes=(0, 0), axis_name=AXIS)(
        state, grads
    )
    np.testing.assert_array_equal(np.asarray(g_ref["w"]), np.asarray(g_wire["w"]))
    for k in ("h_local", "h_global"):
        np.testing.assert_array_equal(
            np.asarray(s_ref[k]["w"]), np.asarray(s_wire[k]["w"])
        )
    np.testing.assert_array_equal(
        np.asarray(g_ref["w"]), np.asarray(s_ref["h_global"]["w"])
    )


def test_intdiana_pipelined_estimator_unbiased():
    """The microbatch-pipelined IntDIANA round (encode_ints(n_accum=M) ×M,
    accumulate, finish_pipelined) must recover the true gradient mean to
    quantization precision. Regression: every image must carry the FULL
    local shift — a per-image h_i/M dilution decodes to
    ḡ + h̄·(1-1/M) (shift subtracted twice-diluted) and drifts h_local
    toward M·ḡ, i.e. the applied update compounds to ~M× the gradient."""
    from repro.core.scaling import AlphaState

    n_micro, d = 2, 64
    comp = make_compressor("intdiana", stochastic=False)
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (N, n_micro, d))
    h0 = jax.random.normal(jax.random.fold_in(key, 1), (N, d))
    state = comp.init({"w": g[0, 0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)
    state = dict(state, h_local={"w": h0},
                 h_global={"w": jnp.broadcast_to(h0.mean(0), (N, d))})
    # α = η√d/(√n·√r) = 1e6: rounding error ~5e-7, far below the h̄-scale
    # bias the dilution bug would produce, and far inside the int32 clip
    state["alpha"] = AlphaState(
        r=jnp.full((N,), 1.6e-11), step=jnp.ones((N,), jnp.int32)
    )

    def worker(s, gw):
        int_acc = local_acc = alphas = None
        for m in range(n_micro):
            ints, alphas = comp.encode_ints(
                s, {"w": gw[m]}, key=jax.random.PRNGKey(m),
                eta=jnp.float32(1.0), ctx=CTX, n_accum=n_micro,
            )
            local_acc = (ints if local_acc is None
                         else jax.tree.map(jnp.add, local_acc, ints))
            _, int_sum = CTX.psum_wire(ints, comp.wire_format)
            int_acc = (int_sum if int_acc is None
                       else jax.tree.map(jnp.add, int_acc, int_sum))
        return comp.finish_pipelined(
            s, int_acc, local_acc, alphas, ctx=CTX, n_accum=n_micro
        )

    ghat, s2 = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, g)
    true_mean = np.asarray(g.mean(axis=(0, 1)))
    np.testing.assert_allclose(
        np.asarray(ghat["w"][0]), true_mean, atol=1e-4
    )
    # DIANA shift recursion: h_i' = h_i + mean_m Q(g_i^m - h_i) -> mean g_i^m
    np.testing.assert_allclose(
        np.asarray(s2["h_local"]["w"]), np.asarray(g.mean(axis=1)), atol=1e-4
    )
    # global shift advanced to ĝ, identically on every worker
    np.testing.assert_allclose(
        np.asarray(s2["h_global"]["w"][0]), np.asarray(ghat["w"][0]), atol=1e-6
    )


def test_fused_capability_flags():
    """The capability matrix the fused route dispatches on: wire-level
    compressors advertise it, gather-style baselines do not."""
    from repro.core import (
        HeuristicIntSGD, IntDIANA, IntSGD, NatSGD, PowerSGD, QSGD, SignSGD,
        TopK,
    )

    assert IntSGD.fused_capable and IntDIANA.fused_capable
    assert IntDIANA.fused_local_state and not IntSGD.fused_local_state
    for c in (QSGD, NatSGD, PowerSGD, SignSGD, TopK, HeuristicIntSGD):
        assert not c.fused_capable, c


def test_allreduce_vs_allgather_flag():
    from repro.core import QSGD, IntSGD, NatSGD, PowerSGD, TopK

    assert IntSGD.supports_allreduce and PowerSGD.supports_allreduce
    assert not QSGD.supports_allreduce
    assert not NatSGD.supports_allreduce
    assert not TopK.supports_allreduce


def test_powersgd_converges_low_rank():
    """PowerSGD+EF drives a low-rank-target quadratic to the optimum (its
    natural regime); full-rank targets need the EF-theory step size lr∝δ."""
    from repro.core.simulate import SimTrainer
    from repro.optim import sgd
    from repro.optim.schedules import constant

    n = 4
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (n, 40, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, 2, 40))
    W = jnp.einsum("nik,nkj->nij", u, v)

    def loss(p, b):
        return 0.5 * jnp.sum((p["W"] - b) ** 2)

    tr = SimTrainer(
        loss, n, make_compressor("powersgd", min_compress_size=100),
        sgd(), constant(0.1),
    )
    st = tr.init({"W": jnp.zeros((40, 40))})
    for _ in range(300):
        st, _ = tr.step(st, W)
    err = float(jnp.linalg.norm(st.params["W"] - W.mean(0)))
    assert err < 1e-2, err
