"""Compressor contracts under the n-worker vmap simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.comm import CommCtx
from repro.core.compressor import aggregate_exact

N = 4
AXIS = "workers"
CTX = CommCtx(axes=(AXIS,), axis_sizes=(N,))


def _run_round(comp, grads_per_worker, key=None, eta=0.1):
    key = key if key is not None else jax.random.PRNGKey(0)
    state = comp.init(jax.tree.map(lambda x: x[0], grads_per_worker))
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)

    def worker(s, g):
        return comp.aggregate(s, g, key=key, eta=jnp.float32(eta), ctx=CTX)

    ghat, new_state, metrics = jax.vmap(
        worker, in_axes=(0, 0), axis_name=AXIS
    )(state, grads_per_worker)
    return jax.tree.map(lambda x: x[0], ghat), new_state, metrics


def _grads(key, shape=(64,)):
    return {"w": jax.random.normal(key, (N,) + shape)}


@pytest.mark.parametrize(
    "name", ["none", "intsgd", "intsgd_determ", "intsgd_block", "intsgd8",
             "heuristic_intsgd", "qsgd", "natsgd", "powersgd", "signsgd",
             "topk", "intdiana", "allgather_sgd"],
)
def test_aggregate_identical_across_workers(name):
    """The decoded estimate must be IDENTICAL on every worker (the property
    that lets all workers apply the same update without a broadcast)."""
    comp = make_compressor(name)
    grads = _grads(jax.random.PRNGKey(1))
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)

    def worker(s, g):
        g_, s_, m = comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    ghat_all = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    for i in range(1, N):
        np.testing.assert_allclose(
            ghat_all["w"][0], ghat_all["w"][i], rtol=1e-6, atol=1e-7
        )


@pytest.mark.parametrize("name", ["intsgd", "qsgd", "natsgd"])
def test_unbiased_compressors(name):
    """E[ghat] == mean(grads) for the unbiased families (MC over keys).

    IntSGD needs a warmed α state (r_k > 0): with r=0 (the k=0 state) α is
    degenerate, which is exactly why the paper makes the first communication
    exact — asserted separately in test_intsgd_step0_state_is_degenerate."""
    from repro.core.scaling import AlphaState

    comp = make_compressor(name)
    grads = _grads(jax.random.PRNGKey(2), (32,))
    target = np.asarray(jnp.mean(grads["w"], axis=0))

    state0 = comp.init({"w": grads["w"][0]})
    state0 = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state0)
    if name == "intsgd":
        state0 = AlphaState(r=jnp.full((N,), 1e-2), step=jnp.ones((N,), jnp.int32))

    def worker(s, g, key):
        g_, _, _ = comp.aggregate(
            s, g, key=key, eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    acc = np.zeros(32)
    trials = 300
    for t in range(trials):
        ghat = jax.vmap(worker, in_axes=(0, 0, None), axis_name=AXIS)(
            state0, grads, jax.random.PRNGKey(100 + t)
        )
        acc += np.asarray(ghat["w"][0])
    err = np.abs(acc / trials - target).max()
    assert err < 0.05, (name, err)


def test_intsgd_step0_state_is_degenerate():
    """With the k=0 state (r=0) the decoded aggregate is badly biased —
    the reason Algorithm 1 makes the first communication exact."""
    comp = make_compressor("intsgd")
    grads = _grads(jax.random.PRNGKey(2), (32,))
    ghat, _, _ = _run_round(comp, grads)
    target = np.asarray(jnp.mean(grads["w"], axis=0))
    assert np.abs(np.asarray(ghat["w"]) - target).max() > 0.05


def test_intsgd_exact_when_alpha_huge():
    """As α→∞ quantization vanishes: IntSGD(Random) == exact mean."""
    from repro.core.compressor import IntSGD
    from repro.core.scaling import AlphaMovingAvg, AlphaState

    comp = IntSGD(alpha_rule=AlphaMovingAvg(eps=1e-12))
    grads = _grads(jax.random.PRNGKey(3), (16,))
    # state with r=0 -> alpha = sqrt(d)/eps = gigantic
    state = AlphaState(r=jnp.zeros((N,)), step=jnp.ones((N,), jnp.int32))

    def worker(s, g):
        g_, _, _ = comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )
        return g_

    ghat = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    exact = jnp.mean(grads["w"], axis=0)
    # alpha huge -> ints clipped... bits=32 lim=2^31/4: alpha*g may exceed ->
    # this is exactly why the paper needs the first-step-exact convention;
    # here we only check the decode matches within clip-free range
    mask = np.abs(np.asarray(grads["w"])).max(0) * 1e10 < 2**31 / N
    got = np.asarray(ghat["w"][0])
    want = np.asarray(exact)
    np.testing.assert_allclose(got[mask], want[mask], rtol=1e-4, atol=1e-6)


def test_error_feedback_accumulates():
    """EF invariant: e' = (g + e) - C(g + e) for each worker independently."""
    comp = make_compressor("signsgd")
    grads = _grads(jax.random.PRNGKey(4), (32,))
    ghat, new_state, _ = _run_round(comp, grads)
    work = np.asarray(grads["w"])  # e=0 initially
    scale = np.mean(np.abs(work), axis=-1, keepdims=True)
    local_c = scale * np.sign(work)
    np.testing.assert_allclose(
        np.asarray(new_state["w"]), work - local_c, rtol=1e-5, atol=1e-6
    )


def test_intdiana_shift_tracking():
    """h_local += Q(g - h); after one round with h=0, h_local == Q(g_i)."""
    comp = make_compressor("intdiana")
    grads = _grads(jax.random.PRNGKey(5), (16,))
    state = comp.init({"w": grads["w"][0]})
    state = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + jnp.shape(x)), state)
    # make alpha well-defined: r>0
    state["alpha"] = jax.tree.map(
        lambda x: jnp.ones_like(x) if x.dtype != jnp.int32 else x, state["alpha"]
    )

    def worker(s, g):
        return comp.aggregate(
            s, g, key=jax.random.PRNGKey(0), eta=jnp.float32(0.1), ctx=CTX
        )

    ghat, new_state, m = jax.vmap(worker, in_axes=(0, 0), axis_name=AXIS)(state, grads)
    # global shift advanced by mean of quantized diffs == ghat (h started at 0)
    np.testing.assert_allclose(
        np.asarray(new_state["h_global"]["w"][0]), np.asarray(ghat["w"][0]), rtol=1e-6
    )
    # per-worker shifts differ (heterogeneous grads) — the per-worker state
    h = np.asarray(new_state["h_local"]["w"])
    assert not np.allclose(h[0], h[1])


def test_allreduce_vs_allgather_flag():
    from repro.core import QSGD, IntSGD, NatSGD, PowerSGD, TopK

    assert IntSGD.supports_allreduce and PowerSGD.supports_allreduce
    assert not QSGD.supports_allreduce
    assert not NatSGD.supports_allreduce
    assert not TopK.supports_allreduce


def test_powersgd_converges_low_rank():
    """PowerSGD+EF drives a low-rank-target quadratic to the optimum (its
    natural regime); full-rank targets need the EF-theory step size lr∝δ."""
    from repro.core.simulate import SimTrainer
    from repro.optim import sgd
    from repro.optim.schedules import constant

    n = 4
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (n, 40, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, 2, 40))
    W = jnp.einsum("nik,nkj->nij", u, v)

    def loss(p, b):
        return 0.5 * jnp.sum((p["W"] - b) ** 2)

    tr = SimTrainer(
        loss, n, make_compressor("powersgd", min_compress_size=100),
        sgd(), constant(0.1),
    )
    st = tr.init({"W": jnp.zeros((40, 40))})
    for _ in range(300):
        st, _ = tr.step(st, W)
    err = float(jnp.linalg.norm(st.params["W"] - W.mean(0)))
    assert err < 1e-2, err
