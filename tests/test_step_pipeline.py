"""The unified step-builder pipeline: fused Pallas routing parity against
the unfused ZeRO-1 path, the eval builder, and routing validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.core import make_compressor
from repro.data.synthetic import SyntheticLMData
from repro.launch.step import build_eval_step, build_init_state, build_train_step
from repro.models.transformer import init_lm_params
from repro.optim import adamw, sgd
from repro.optim.schedules import constant


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _run_steps(cfg, mesh, shape, *, fused, steps=4, compressor="intsgd",
               wire=None, opt=None, lr=0.2):
    comp = make_compressor(compressor)
    opt = opt if opt is not None else sgd(momentum=0.9, weight_decay=1e-4)
    art = build_train_step(
        cfg, mesh, shape, compressor=comp, base_opt=opt,
        lr_schedule=constant(lr), param_dtype=jnp.float32,
        fused=fused, donate=False, wire=wire,
    )
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg, tp=1, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(
        cfg, mesh, compressor=comp, base_opt=opt, fused=fused
    )
    opt_state, comp_state = init(params)
    data = SyntheticLMData(cfg.vocab, shape.seq_len, shape.global_batch, seed=0)
    bs = art.in_shardings[5]
    losses = []
    for i in range(steps):
        batch = {k: jax.device_put(v, bs[k]) for k, v in data.batch(i, 0).items()}
        fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
        params, opt_state, comp_state, loss, _ = fn(
            params, opt_state, comp_state, jnp.int32(i),
            jax.random.fold_in(key, i), batch,
        )
        losses.append(float(loss))
    return params, losses


@pytest.mark.slow
def test_fused_route_matches_unfused(mesh):
    """The Pallas fused dequantize+SGD routing (CPU interpret mode) must
    match the unfused decode + ZeRO-1 update to ULP-scale tolerance: the
    integer wire is identical, only the update arithmetic is fused."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    p_ref, l_ref = _run_steps(cfg, mesh, shape, fused=False)
    p_fus, l_fus = _run_steps(cfg, mesh, shape, fused=True)
    np.testing.assert_allclose(np.asarray(l_fus), np.asarray(l_ref), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fus)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6
        )


@pytest.mark.slow
@pytest.mark.parametrize("fused", [False, True])
def test_packed_wire_matches_dense_route(mesh, fused):
    """build_train_step over the PackedInt wire must match the DenseInt
    route step-for-step (both routes, same integer image — only the
    transport words differ). The 4-device-mesh version of this parity lives
    in test_distributed.py::test_packed_wire_parity_on_mesh."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    p_d, l_d = _run_steps(
        cfg, mesh, shape, fused=fused, compressor="intsgd8", wire="dense8"
    )
    p_p, l_p = _run_steps(
        cfg, mesh, shape, fused=fused, compressor="intsgd8", wire="packed8"
    )
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_d), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_d), jax.tree.leaves(p_p)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6
        )


@pytest.mark.slow
@pytest.mark.parametrize("compressor,wire", [("intsgd8", "packed8"),
                                             ("intdiana", None)])
def test_fused_adamw_matches_unfused(mesh, compressor, wire):
    """The fused decode+AdamW kernel route (bias-corrected moments updated
    in-register) must match the unfused decode + ZeRO-1 AdamW update to
    ULP-scale tolerance, for plain IntSGD and for the IntDIANA shifted
    decode. The 4-device-mesh matrix lives in
    test_distributed.py::test_fused_family_parity_on_mesh."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    p_ref, l_ref = _run_steps(
        cfg, mesh, shape, fused=False, compressor=compressor, wire=wire,
        opt=adamw(), lr=0.01,
    )
    p_fus, l_fus = _run_steps(
        cfg, mesh, shape, fused=True, compressor=compressor, wire=wire,
        opt=adamw(), lr=0.01,
    )
    np.testing.assert_allclose(np.asarray(l_fus), np.asarray(l_ref), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fus)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6
        )


@pytest.mark.slow
def test_eval_step_matches_train_loss(mesh):
    """build_eval_step is the train body's forward stage: on identical
    (params, batch) it must report the train step's pre-update loss."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    comp = make_compressor("intsgd")
    opt = sgd(momentum=0.9)
    art = build_train_step(
        cfg, mesh, shape, compressor=comp, base_opt=opt,
        lr_schedule=constant(0.1), param_dtype=jnp.float32, donate=False,
    )
    ev = build_eval_step(cfg, mesh, shape, param_dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    params = init_lm_params(key, cfg, tp=1, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt)
    opt_state, comp_state = init(params)
    data = SyntheticLMData(cfg.vocab, shape.seq_len, shape.global_batch, seed=1)
    bs = art.in_shardings[5]
    batch = {k: jax.device_put(v, bs[k]) for k, v in data.batch(0, 0).items()}
    _, _, _, train_loss, _ = art.jitted["exact"](
        params, opt_state, comp_state, jnp.int32(0), key, batch
    )
    eval_loss = ev.jitted["eval"](params, batch)
    np.testing.assert_allclose(
        float(eval_loss), float(train_loss), rtol=1e-6
    )


def test_fused_route_capability_errors(mesh):
    """Pairs outside the fused capability matrix must fail at build time
    naming the MISSING CAPABILITY (Compressor.fused_capable /
    Optimizer.fused_kernel), not a concrete type — the routing contract is
    capability dispatch, so the error has to teach the capability."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    shape = ShapeConfig("t", 32, 4, "train")
    # compressor without wire-level aggregation: names fused_capable and the
    # compressor, not "isinstance of IntSGD"
    with pytest.raises(ValueError, match="fused_capable") as ei:
        build_train_step(
            cfg, mesh, shape, compressor=make_compressor("qsgd"),
            base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1), fused=True,
        )
    assert "qsgd" in str(ei.value)
    assert "IntSGD" not in str(ei.value)
    # optimizer without a fused kernel form (nesterov): names fused_kernel
    with pytest.raises(ValueError, match="fused_kernel"):
        build_train_step(
            cfg, mesh, shape, compressor=make_compressor("intsgd"),
            base_opt=sgd(momentum=0.9, nesterov=True),
            lr_schedule=constant(0.1), fused=True,
        )
    # the capability survives neither opaque wrapping...
    from repro.optim.base import chain_clip_by_global_norm

    with pytest.raises(ValueError, match="fused_kernel"):
        build_train_step(
            cfg, mesh, shape, compressor=make_compressor("intsgd"),
            base_opt=chain_clip_by_global_norm(sgd(momentum=0.9), 1.0),
            lr_schedule=constant(0.1), fused=True,
        )
    # ...while every capable pair builds: {sgd, adamw} × {intsgd, intdiana}
    for opt in (sgd(momentum=0.9), adamw()):
        for comp in ("intsgd", "intdiana"):
            build_train_step(
                cfg, mesh, shape, compressor=make_compressor(comp),
                base_opt=opt, lr_schedule=constant(0.1), fused=True,
            )
