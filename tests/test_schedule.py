"""PR 9: the static performance auditor — schedule (P) + traffic (T) layers.

Four groups:

  * PLANTED REGRESSIONS, one per P-rule: serialize a bucket consumer into
    the next image's backward (P001), duplicate / kill / cast-round-trip a
    wire collective (P002), widen a fused kernel operand past the codec's
    per-image byte budget (P003) — each must be flagged BY RULE ID, and the
    un-planted twin must stay clean;
  * the BYTE ACCOUNTANT's equality contract: the static transport model
    (``repro.analysis.traffic``) meters exactly what the ``Logged`` codec
    meters and what ``BucketManifest`` records, across every codec × worker
    count × microbatch count (hypothesis property + deterministic pins);
  * T-rule drift: an eqn-level transport that disagrees with the declared
    model (wrong bytes, wrong collective count) is named T001/T002;
  * the COMPOSED audit (`full_audit` / `verify_step`): suppression spans
    W/P/T, and the real 4-device trace passes all three layers with the
    roofline the overlap design promises.
"""
import os
import textwrap

import pytest

import jax
import jax.numpy as jnp
from jax import lax

from conftest import REPO, run_forced_mesh as _run

from repro.analysis import jaxpr_walk as jw
from repro.analysis import schedule as sched
from repro.analysis import traffic as tr
from repro.analysis import wire_audit as wa
from repro.parallel import collectives as coll
from repro.wire import Logged, make_wire_format, plan_buckets

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# toy tracing helpers (the test_analysis.py pattern: 1-device mesh keeps the
# collective eqns in the jaxpr; the SPEC declares what is proven)
# ---------------------------------------------------------------------------
def _toy_jaxpr(body, *structs):
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    sm = coll.shard_map(
        body, mesh=mesh, in_specs=(P(),) * len(structs), out_specs=P()
    )
    return jax.make_jaxpr(sm)(*structs)


def _spec(**kw):
    base = dict(
        dp_axes=("data",), axis_sizes={"data": 4}, n_workers=4,
        wire_kind="dense", bits=8,
    )
    base.update(kw)
    return wa.WireSpec(**base)


F32 = jax.ShapeDtypeStruct((256,), jnp.float32)


def _ints(x):
    return jnp.clip(jnp.round(x), -3, 3).astype(jnp.int32)


def _rules(report):
    return sorted({v.rule for v in report.violations})


# ---------------------------------------------------------------------------
# P001: a reduce's result feeding compute a later reduce depends on
# ---------------------------------------------------------------------------
def test_p001_decoded_sum_feeds_next_images_backward():
    def step(x, w):
        # image 0's reduce ...
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        s0 = lax.psum(_ints(x), "data")
        # ... DECODED INTO image 1's matmul: the planted pipeline break
        y = jnp.dot(s0.astype(jnp.float32).reshape(16, 16), w)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        s1 = lax.psum(_ints(y.reshape(-1)), "data")
        return s0.sum() + s1.sum()

    closed = _toy_jaxpr(step, F32, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    rep = sched.analyze_schedule(closed, _spec())
    assert any(v.rule == "P001" for v in rep.violations), _rules(rep)
    assert "pipelining is" in " ".join(
        v.message for v in rep.violations if v.rule == "P001"
    )


def test_p001_independent_images_clean():
    def step(x, y):
        # two data-independent images: reduces may land in either order
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        s0 = lax.psum(_ints(x), "data")
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        s1 = lax.psum(_ints(y), "data")
        return s0.sum() + s1.sum()

    closed = _toy_jaxpr(step, F32, F32)
    rep = sched.analyze_schedule(closed, _spec())
    assert not any(v.rule == "P001" for v in rep.violations), _rules(rep)
    # and the roofline sees the wire-wire concurrency
    assert rep.n_wire_collectives == 2
    assert rep.n_serialized == 0
    assert rep.interleavable_fraction == 1.0


# ---------------------------------------------------------------------------
# P002: dead / duplicate collectives, cast round-trips
# ---------------------------------------------------------------------------
def test_p002_duplicate_psum_flagged():
    def step(x):
        ints = _ints(x)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        a = lax.psum(ints, "data")
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        b = lax.psum(ints, "data")  # the same sum, twice on the wire
        return a + b

    rep = sched.analyze_schedule(_toy_jaxpr(step, F32), _spec())
    dups = [v for v in rep.violations
            if v.rule == "P002" and "duplicate" in v.message]
    assert len(dups) == 1, _rules(rep)


def test_p002_dead_collective_flagged():
    def step(x):
        ints = _ints(x)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        dead = lax.psum(ints, "data")  # never reaches the outputs
        del dead
        return ints.sum()

    rep = sched.analyze_schedule(_toy_jaxpr(step, F32), _spec())
    assert any(
        v.rule == "P002" and "dead" in v.message for v in rep.violations
    ), _rules(rep)


def test_p002_int_cast_roundtrip_flagged():
    def step(x):
        ints = _ints(x)
        narrowed = ints.astype(jnp.int16).astype(jnp.int32)  # the round-trip
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(narrowed, "data")

    rep = sched.analyze_schedule(_toy_jaxpr(step, F32), _spec())
    trips = [v for v in rep.violations
             if v.rule == "P002" and "round-trip" in v.message]
    assert trips, _rules(rep)
    assert "int16" in trips[0].where


def test_p002_float_mixed_precision_chain_not_flagged():
    # f32 -> bf16 compute -> f32 grads is the mixed-precision recipe, not
    # wasted wire work: the round-trip rule is integer-only
    def step(x):
        h = x.astype(jnp.bfloat16)
        g = (h * 2).astype(jnp.float32)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(_ints(g), "data")

    rep = sched.analyze_schedule(_toy_jaxpr(step, F32), _spec())
    assert not any(v.rule == "P002" for v in rep.violations), _rules(rep)


# ---------------------------------------------------------------------------
# P003: fused-route per-eqn HBM byte budget (both codecs)
# ---------------------------------------------------------------------------
def _fused_spec(**kw):
    return _spec(
        wire_kind="packed", bits=8, use_kernels=True, fused=True, **kw
    )


def test_p003_widened_fused_operand_flagged():
    kops = pytest.importorskip("repro.kernels.ops")

    def step(image, param, mom):
        scal = jnp.ones((5,), jnp.float32)
        p, (m,), _ = kops.fused_apply(
            image, param, (mom,), scal, kernel="sgd", interpret=True
        )
        return p + 0.0 * m

    structs = (
        jax.ShapeDtypeStruct((1024,), jnp.int32),  # 4096 B for a 1024 B budget
        jax.ShapeDtypeStruct((1024,), jnp.float32),
        jax.ShapeDtypeStruct((1024,), jnp.float32),
    )
    rep = sched.analyze_schedule(
        jax.make_jaxpr(step)(*structs), _fused_spec()
    )
    p3 = [v for v in rep.violations if v.rule == "P003"]
    assert p3, _rules(rep)
    assert "budget" in p3[0].message


def test_p003_packed_words_within_budget_clean():
    kops = pytest.importorskip("repro.kernels.ops")

    def step(words, param, mom):
        scal = jnp.ones((5,), jnp.float32)
        p, (m,), _ = kops.fused_unpack_apply(
            words, param, (mom,), scal, None,
            kernel="sgd", bits=8, n_summed=4, interpret=True,
        )
        return p + 0.0 * m

    structs = (
        jax.ShapeDtypeStruct((256,), jnp.int32),  # 1024 B == the budget
        jax.ShapeDtypeStruct((1024,), jnp.float32),
        jax.ShapeDtypeStruct((1024,), jnp.float32),
    )
    rep = sched.analyze_schedule(
        jax.make_jaxpr(step)(*structs), _fused_spec()
    )
    assert not any(v.rule == "P003" for v in rep.violations), _rules(rep)


# ---------------------------------------------------------------------------
# schedule classification: serialized vs eligible
# ---------------------------------------------------------------------------
def test_monolithic_psum_is_serialized():
    def step(x):
        # every value feeds the reduce, nothing is concurrent with it
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(_ints(x), "data")

    rep = sched.analyze_schedule(_toy_jaxpr(step, F32), _spec())
    assert rep.n_wire_collectives == 1
    assert rep.n_serialized == 1
    assert rep.hidden_fraction == 0.0
    assert rep.interleavable_fraction == 0.0


def test_concurrent_dot_makes_collective_hideable():
    def step(x, a, b):
        # the matmul neither feeds nor consumes the reduce: hideable work
        y = jnp.dot(a, b)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        s = lax.psum(_ints(x), "data")
        return s.sum() + y.sum()

    m = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    rep = sched.analyze_schedule(_toy_jaxpr(step, F32, m, m), _spec())
    assert rep.n_wire_collectives == 1
    assert rep.n_serialized == 0
    assert rep.hidden_fraction == 1.0
    row = rep.collectives[0]
    assert row["eligible"] and row["concurrent_flops"] >= 2 * 32 * 32 * 32


# ---------------------------------------------------------------------------
# the byte accountant == Logged metering == BucketManifest
# ---------------------------------------------------------------------------
ALL_CODECS = ["dense4", "dense8", "dense16", "dense32",
              "packed4", "packed8", "packed16",
              "topk8:32", "topk16:8"]


def _meter_logged(codec, leaf_sizes, n, M):
    """Trace M images' worth of pack calls through a Logged codec and return
    the metered wire bytes (trace only, nothing executed)."""
    logged = Logged(make_wire_format(codec))

    def pack_all():
        return [
            logged.pack(jnp.zeros((s,), jnp.int32), n_workers=n)
            for _ in range(M)
            for s in leaf_sizes
        ]

    jax.eval_shape(pack_all)
    return logged.pack_bytes


def _declared_leaf_bytes(wf, size):
    """The accountant's per-leaf arithmetic for any codec kind."""
    return tr.payload_bytes(wf.name, wf.bits, size, k=getattr(wf, "k", 0))


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_static_payload_equals_logged_metering(codec):
    wf = make_wire_format(codec)
    leaf_sizes, n, M = (129, 64, 7), 4, 2
    declared = sum(_declared_leaf_bytes(wf, s) for s in leaf_sizes) * M
    assert declared == _meter_logged(codec, leaf_sizes, n, M)
    # and the per-leaf arithmetic IS the codec's own wire_bytes
    for s in leaf_sizes:
        assert _declared_leaf_bytes(wf, s) == wf.wire_bytes(s)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        codec=st.sampled_from(ALL_CODECS),
        leaf_sizes=st.lists(
            st.integers(min_value=1, max_value=300), min_size=1, max_size=4
        ),
        n=st.integers(min_value=1, max_value=8),
        M=st.integers(min_value=1, max_value=4),
    )
    def test_static_accountant_matches_logged_property(
        codec, leaf_sizes, n, M
    ):
        wf = make_wire_format(codec)
        declared = sum(_declared_leaf_bytes(wf, s) for s in leaf_sizes) * M
        assert declared == _meter_logged(codec, leaf_sizes, n, M)


def test_plan_bucket_sizes_matches_plan_buckets():
    wf = make_wire_format("packed8")
    leaf_sizes = (5000, 3000, 171)
    words_struct = jax.eval_shape(
        lambda: [
            wf.pack(jnp.zeros((s,), jnp.int32), n_workers=4)
            for s in leaf_sizes
        ]
    )
    manifest = plan_buckets(words_struct, bucket_words=512)
    total_words = sum(
        tr.leaf_wire_words("packed", 8, s) for s in leaf_sizes
    )
    assert manifest.total_words == total_words
    assert manifest.bucket_sizes == tr.plan_bucket_sizes(total_words, 512)
    assert manifest.payload_bytes == sum(
        tr.payload_bytes("packed", 8, s) for s in leaf_sizes
    )


def test_manifest_ring_collectives_matches_transport_plan():
    wf = make_wire_format("packed8")
    leaf_sizes, n, M, B = (5000, 3000, 171), 4, 2, 512
    words_struct = jax.eval_shape(
        lambda: [
            wf.pack(jnp.zeros((s,), jnp.int32), n_workers=n)
            for s in leaf_sizes
        ]
    )
    manifest = plan_buckets(words_struct, bucket_words=B)
    spec = _spec(
        axis_sizes={"data": n}, n_workers=n, n_accum=M,
        wire_kind="packed", bits=8, leaf_sizes=leaf_sizes,
        overlap="ring", bucket_words=B,
    )
    plan = tr.plan_transport(spec)
    ring_eqns, ring_bytes = manifest.ring_collectives((n,))
    assert ring_eqns * M == plan.n_eqns
    assert ring_bytes * M == plan.coll_bytes
    # a size-1 axis short-circuits: no collectives at all
    assert manifest.ring_collectives((1,)) == (0, 0)


# ---------------------------------------------------------------------------
# T-rules: eqn-level drift from the declared transport
# ---------------------------------------------------------------------------
def test_traffic_serial_route_clean():
    def step(x):
        # dense8 transport: one int8 psum carrying exactly size bytes
        ints = jnp.clip(jnp.round(x), -3, 3).astype(jnp.int8)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    spec = _spec(leaf_sizes=(256,), overlap="off")
    rep = tr.account_traffic(_toy_jaxpr(step, F32), spec)
    assert rep.ok, _rules(rep)
    assert rep.observed_eqns == rep.plan.n_eqns == 1
    assert rep.observed_bytes == rep.plan.coll_bytes == 256


def test_t001_widened_wire_flagged():
    def step(x):
        ints = _ints(x)  # int32 on the wire: 4x the declared dense8 payload
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(ints, "data")

    spec = _spec(leaf_sizes=(256,), overlap="off")
    rep = tr.account_traffic(_toy_jaxpr(step, F32), spec)
    assert _rules(rep) == ["T001"]
    assert "1024 != declared transport 256" in rep.violations[0].message


def test_t002_split_collective_flagged():
    def step(x):
        ints = jnp.clip(jnp.round(x), -3, 3).astype(jnp.int8)
        # same payload, two eqns: count drift without byte drift
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        a = lax.psum(ints[:128], "data")
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        b = lax.psum(ints[128:], "data")
        return jnp.concatenate([a, b])

    spec = _spec(leaf_sizes=(256,), overlap="off")
    rep = tr.account_traffic(_toy_jaxpr(step, F32), spec)
    assert _rules(rep) == ["T002"]


def test_traffic_skipped_without_leaf_sizes():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(_ints(x), "data")

    rep = tr.account_traffic(_toy_jaxpr(step, F32), _spec())
    assert rep.plan is None and rep.ok  # hand-built spec: nothing declared


# ---------------------------------------------------------------------------
# the composed audit: suppression + report shape
# ---------------------------------------------------------------------------
def test_full_audit_suppression_spans_rule_families():
    def step(x):
        ints = _ints(x)
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        a = lax.psum(ints, "data")
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        b = lax.psum(ints, "data")  # planted P002 duplicate
        return a + b

    closed = _toy_jaxpr(step, F32)
    spec = _spec(bits=32, wire_kind="dense")
    rep = sched.full_audit(closed, spec)
    assert any(v.rule == "P002" for v in rep.violations)
    waived = sched.full_audit(
        closed, spec, suppress={"P002": "planted fixture for this test"}
    )
    assert not any(v.rule == "P002" for v in waived.violations)
    assert any(v.rule == "P002" for v, _why in waived.suppressed)
    with pytest.raises(ValueError, match="unknown rule"):
        sched.full_audit(closed, spec, suppress={"Z999": "nope"})
    with pytest.raises(ValueError, match="justification"):
        sched.full_audit(closed, spec, suppress={"P002": "  "})


def test_full_report_dict_has_all_sections():
    def step(x):
        # lint: allow(C001) -- audit fixture: the raw collective IS the subject under test
        return lax.psum(_ints(x), "data")

    d = sched.full_audit(_toy_jaxpr(step, F32), _spec(bits=32)).to_dict()
    assert "schedule" in d and "traffic" in d
    assert {"hidden_fraction", "interleavable_fraction", "collectives"} \
        <= set(d["schedule"])
    assert {"declared", "observed_eqns", "observed_bytes"} \
        <= set(d["traffic"])


def test_matrix_diff_ignores_timing_and_names_drift():
    from repro.analysis.__main__ import _diff_reports

    base = {
        "points": [
            {"config": "a", "codec": "packed8", "overlap": "off",
             "microbatches": 1, "fused": False, "ok": True,
             "violations": [], "seconds": 1.0},
            {"config": "a", "codec": "packed8", "overlap": "ring",
             "microbatches": 4, "fused": False, "ok": True,
             "violations": [], "seconds": 2.0},
        ],
        "lint": [],
    }
    import copy

    same = copy.deepcopy(base)
    same["points"][0]["seconds"] = 99.0  # timings churn freely
    assert _diff_reports(base, same) == []

    removed = copy.deepcopy(base)
    removed["points"].pop()
    drift = _diff_reports(base, removed)
    assert len(drift) == 1 and "removed" in drift[0]

    flipped = copy.deepcopy(base)
    flipped["points"][1]["ok"] = False
    flipped["points"][1]["violations"] = [
        {"rule": "T001", "where": "w", "message": "m"}
    ]
    drift = _diff_reports(base, flipped)
    assert len(drift) == 1
    assert "verdict changed" in drift[0] and "T001" in drift[0]


def test_rule_ids_disjoint_across_families():
    fams = [wa.RULES, sched.RULES, tr.RULES]
    ids = [r for fam in fams for r in fam]
    assert len(ids) == len(set(ids))
    assert {r[0] for r in ids} == {"W", "P", "T"}


# ---------------------------------------------------------------------------
# the real thing: 4-device forced-mesh trace through all three layers
# ---------------------------------------------------------------------------
def test_forced_mesh_full_audit_and_roofline():
    """ring × M=2 on 4 workers: W/P/T all clean, byte/count equality exact,
    and the static roofline certifies the pipelined wire as interleavable —
    while the serial M=1 psum stays serialized. Also exercises
    build_train_step(verify='static') end to end."""
    _run(
        textwrap.dedent(
            """
            import jax
            from repro.analysis import schedule as sched
            from repro.configs import ShapeConfig, get_arch, smoke_config
            from repro.core import make_compressor
            from repro.launch.step import build_train_step
            from repro.optim import sgd
            from repro.optim.schedules import constant

            mesh = jax.make_mesh((4, 1), ("data", "model"))

            def build(**kw):
                return build_train_step(
                    smoke_config(get_arch("xlstm-125m")), mesh,
                    ShapeConfig("t", 32, 8, "train"),
                    compressor=make_compressor(
                        "intsgd", bits=8, wire="packed8"
                    ),
                    base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
                    tp_override=1, **kw,
                )

            # pipelined ring: verify='static' runs the full W/P/T audit
            art = build(overlap="ring", microbatches=2, verify="static")
            rep = sched.verify_step(art)
            assert rep.ok, rep.violations
            s, t = rep.schedule, rep.traffic
            assert t.plan is not None
            assert t.observed_bytes == t.plan.coll_bytes
            assert t.observed_eqns == t.plan.n_eqns
            assert s.interleavable_fraction == 1.0, s.to_dict()
            assert s.hidden_fraction == 1.0, s.to_dict()

            # monolithic serial psum: structurally serialized
            rep1 = sched.verify_step(build(overlap="off", microbatches=1))
            assert rep1.ok, rep1.violations
            assert rep1.schedule.n_wire_collectives == 1
            assert rep1.schedule.n_serialized == 1
            assert rep1.traffic.observed_bytes == rep1.traffic.plan.coll_bytes
            print("full audit ok")
            """
        )
    )
