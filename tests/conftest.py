import os
import sys

# smoke tests and benches must see exactly ONE device; only dryrun.py forces
# 512 placeholder devices (in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
