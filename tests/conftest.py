import os
import subprocess
import sys

# smoke tests and benches must see exactly ONE device; only dryrun.py forces
# 512 placeholder devices (in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_mesh(script: str, timeout=420):
    """Run `script` in a subprocess with 4 forced host devices, so the
    multi-device tests exercise real shard_map collectives while the parent
    process' single-device view stays untouched. Shared by
    test_distributed.py, test_runtime.py and test_overlap.py — ONE place to
    change the forced-mesh environment."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
