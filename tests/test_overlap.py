"""The overlapped-wire subsystem (PR 3): bucket manifest inversion, the
ppermute ring == psum bit-parity that the overlap contract rests on, the
CommCtx bucketed route, and end-to-end train-step parity on a real 4-device
mesh (fused and unfused, microbatch-pipelined and not)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced_mesh
from repro.core.comm import CommCtx
from repro.parallel import collectives as coll
from repro.wire import (
    DenseInt,
    PackedInt,
    bucketize,
    debucketize,
    plan_buckets,
)

N = 4
AXIS = coll.WORKER_AXIS

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# bucket manifest: exact inversion, zero inflation
# ---------------------------------------------------------------------------
def test_bucketize_roundtrip_ragged():
    words = {
        "a": jnp.arange(257, dtype=jnp.int32),
        "b": jnp.arange(1000, 1030, dtype=jnp.int32).reshape(5, 6),
        "c": jnp.array(7, jnp.int32),  # scalar leaf
    }
    man = plan_buckets(words, bucket_words=64)
    assert man.total_words == 257 + 30 + 1
    assert man.bucket_sizes == (64, 64, 64, 64, 32)
    assert man.payload_bytes == 4 * man.total_words  # no padding, ever
    buckets = bucketize(words, man)
    assert [int(b.size) for b in buckets] == list(man.bucket_sizes)
    back = debucketize(buckets, man)
    for k in words:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(words[k]))


def test_bucketize_single_bucket_and_narrow_lanes():
    # tree smaller than one bucket; int8 dense lanes bucket too
    words = {"w": jnp.arange(-10, 10, dtype=jnp.int8)}
    man = plan_buckets(words, bucket_words=1 << 16)
    assert man.n_buckets == 1 and man.bucket_sizes == (20,)
    assert man.payload_bytes == 20  # 1 byte per int8 lane
    back = debucketize(bucketize(words, man), man)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(words["w"]))


def test_bucketize_rejects_mixed_dtypes_and_bad_sizes():
    with pytest.raises(ValueError, match="dtype"):
        plan_buckets({"a": jnp.zeros(3, jnp.int8), "b": jnp.zeros(3, jnp.int32)})
    with pytest.raises(ValueError, match="positive"):
        plan_buckets({"a": jnp.zeros(3, jnp.int32)}, bucket_words=0)
    man = plan_buckets({"a": jnp.zeros(10, jnp.int32)}, bucket_words=4)
    with pytest.raises(ValueError, match="buckets"):
        debucketize([jnp.zeros(4, jnp.int32)], man)


if HAVE_HYPOTHESIS:

    @given(
        sizes=st.lists(st.integers(1, 400), min_size=1, max_size=5),
        bucket_words=st.integers(1, 512),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_bucketize_roundtrip_property(sizes, bucket_words, seed):
        key = jax.random.PRNGKey(seed)
        words = {
            f"l{i}": jax.random.randint(
                jax.random.fold_in(key, i), (s,), -(2**20), 2**20
            )
            for i, s in enumerate(sizes)
        }
        man = plan_buckets(words, bucket_words=bucket_words)
        assert man.total_words == sum(sizes)
        back = debucketize(bucketize(words, man), man)
        for k in words:
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(words[k]))


# ---------------------------------------------------------------------------
# ring all-reduce == psum, bit-exactly (integer addition is order-free)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int8])
def test_ring_allreduce_matches_psum(dtype):
    key = jax.random.PRNGKey(0)
    lo, hi = (-25, 25) if dtype == jnp.int8 else (-(2**28), 2**28)
    x = jax.random.randint(key, (N, 1003), lo, hi).astype(dtype)

    def ring(v):
        return coll.ring_allreduce_int(v, AXIS, N)

    def ref(v):
        return coll.psum_tree(v, (AXIS,))

    got = coll.vmap_workers(ring, in_axes=0)(x)
    want = coll.vmap_workers(ref, in_axes=0)(x)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ring_allreduce_odd_sizes_and_n1():
    # sizes that don't divide n exercise the ring-chunk padding
    for size in (1, 3, 5, 1001):
        x = jax.random.randint(jax.random.PRNGKey(size), (N, size), -9, 9)
        got = coll.vmap_workers(
            lambda v: coll.ring_allreduce_int(v, AXIS, N), in_axes=0
        )(x)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(x.sum(0)))
    # n == 1 is the identity
    y = jnp.arange(7, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(coll.ring_allreduce_int(y, "unused", 1)), np.asarray(y)
    )


def test_bucketed_psum_rejects_floats():
    with pytest.raises(TypeError, match="integer"):
        coll.psum_wire_words_bucketed(
            [jnp.ones((8,), jnp.float32)], (AXIS,), (N,)
        )


def test_packed_wrap_around_survives_the_ring():
    """The guard-bit invariant through the RING transport: adversarial
    all-workers-at-±lim packed words wrap mod 2^32 identically whether the
    hops run in ring order or psum order."""
    wf = PackedInt(bits=8)
    lim = wf.clip_limit(N)
    ints = jnp.stack([jnp.full((257,), lim if i % 2 else -lim, jnp.int32)
                      for i in range(N)])

    def worker(v):
        words = wf.pack(v, n_workers=N)
        ring = coll.ring_allreduce_int(words, AXIS, N)
        ref = coll.psum_tree(words, (AXIS,))
        return wf.unpack(ring, (257,), n_summed=N), wf.unpack(ref, (257,), n_summed=N)

    got, want = coll.vmap_workers(worker, in_axes=0)(ints)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ints.sum(0)))


# ---------------------------------------------------------------------------
# CommCtx bucketed route parity (the n-worker vmap simulation)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wf", [DenseInt(bits=8), DenseInt(bits=32),
                                PackedInt(bits=8), PackedInt(bits=4)],
                         ids=["dense8", "dense32", "packed8", "packed4"])
def test_psum_wire_overlap_parity(wf):
    """ctx.psum_wire over the bucketed ring == the monolithic psum, for both
    returned views (words AND image), on every codec."""
    ctx_off = CommCtx(axes=(AXIS,), axis_sizes=(N,))
    ctx_ring = CommCtx(axes=(AXIS,), axis_sizes=(N,), overlap="ring",
                       bucket_words=100)
    lim = wf.clip_limit(N)
    key = jax.random.PRNGKey(1)
    ints = {
        "a": jax.random.randint(key, (N, 301), -lim, lim + 1),
        "b": jax.random.randint(jax.random.fold_in(key, 1), (N, 7, 13),
                                -lim, lim + 1),
    }

    def run(ctx):
        def worker(t):
            words, image = ctx.psum_wire(t, wf)
            return words, image

        return coll.vmap_workers(worker, in_axes=0)(ints)

    w_off, s_off = run(ctx_off)
    w_ring, s_ring = run(ctx_ring)
    for a, b in zip(jax.tree.leaves(w_off), jax.tree.leaves(w_ring)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ints:
        np.testing.assert_array_equal(np.asarray(s_off[k]), np.asarray(s_ring[k]))
        np.testing.assert_array_equal(np.asarray(s_ring[k][0]),
                                      np.asarray(ints[k].sum(0)))


def test_commctx_rejects_unknown_overlap():
    with pytest.raises(ValueError, match="overlap"):
        CommCtx(axes=(AXIS,), axis_sizes=(N,), overlap="sideways")


# ---------------------------------------------------------------------------
# end-to-end train-step parity on the real mesh
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_overlap_parity_on_mesh():
    """5 training steps on a 4-device mesh: overlap='ring' (bucketed
    ppermute transport) is BIT-identical to overlap='off' (single psum) in
    loss and params — dense and packed codecs, fused and unfused routes,
    and the microbatch-pipelined body."""
    out = run_forced_mesh(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step, build_init_state
from repro.launch.inputs import materialize_batch
from repro.models.transformer import init_lm_params
from repro.optim import sgd
from repro.optim.schedules import constant

mesh = jax.make_mesh((4, 1), ("data", "model"))
tr = ShapeConfig("t", 32, 8, "train")
cfg = smoke_config(get_arch("xlstm-125m"))
key = jax.random.PRNGKey(0)

def run(wire, fused, overlap, microbatches=1):
    comp = make_compressor("intsgd8")
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    art = build_train_step(cfg, mesh, tr, compressor=comp, base_opt=opt,
                           lr_schedule=constant(0.2), param_dtype=jnp.float32,
                           fused=fused, donate=False, wire=wire,
                           overlap=overlap, bucket_words=2048,
                           microbatches=microbatches)
    params = init_lm_params(key, cfg, tp=1, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt, fused=fused)
    opt_state, comp_state = init(params)
    batch = materialize_batch(cfg, tr, key)
    losses = []
    for i in range(5):
        fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
        params, opt_state, comp_state, loss, _ = fn(
            params, opt_state, comp_state, jnp.int32(i),
            jax.random.fold_in(key, i), batch)
        losses.append(float(loss))
    return params, losses

cases = [("dense8", False, 1), ("packed8", False, 1),
         ("dense8", True, 1), ("packed8", True, 1),
         ("packed8", False, 2)]
for wire, fused, mb in cases:
    p_off, l_off = run(wire, fused, "off", mb)
    p_ring, l_ring = run(wire, fused, "ring", mb)
    assert l_off == l_ring, (wire, fused, mb, l_off, l_ring)
    for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_ring)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("PARITY", wire, "fused" if fused else "zero1", "mb", mb)
print("OVERLAP_PARITY_OK")
"""
    )
    assert "OVERLAP_PARITY_OK" in out
