"""Deliverable (f): per-architecture smoke tests — reduced same-family
configs, one forward/train step on CPU, output shapes + no NaNs, plus a
decode step against the cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch, get_shape, runnable_cells, smoke_config
from repro.launch.inputs import input_specs
from repro.models.common import Axes
from repro.models.decode import init_lm_cache, lm_decode_step, tp_greedy
from repro.models.encdec import (
    encdec_decode_step,
    encdec_loss,
    encdec_prefill,
    init_encdec_cache,
    init_encdec_params,
)
from repro.models.transformer import init_lm_params, lm_loss

ALL_ARCHS = [
    "qwen2.5-32b", "granite-8b", "minitron-4b", "h2o-danube-3-4b",
    "zamba2-2.7b", "internvl2-2b", "deepseek-v2-lite-16b", "mixtral-8x22b",
    "xlstm-125m", "seamless-m4t-medium",
]
AXES = Axes()
B, T = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.frontend_dim)
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 16, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_train_step(name):
    cfg = smoke_config(get_arch(name))
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    if cfg.family == "encdec":
        params = init_encdec_params(key, cfg)
        loss, grads = jax.value_and_grad(
            lambda p: encdec_loss(p, batch, AXES, cfg)
        )(params)
    else:
        params = init_lm_params(key, cfg)
        loss, grads = jax.value_and_grad(lambda p: lm_loss(p, batch, AXES, cfg))(params)
    assert jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(g))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_decode_step(name):
    cfg = smoke_config(get_arch(name))
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    pos = jnp.zeros((B,), jnp.int32)
    if cfg.family == "encdec":
        params = init_encdec_params(key, cfg)
        cache = init_encdec_cache(cfg, 1, 1, B, T, 16)
        frames = jax.random.normal(key, (B, 16, cfg.frontend_dim))
        cache = encdec_prefill(params, frames, cache, AXES, cfg)
        logits, cache2 = encdec_decode_step(params, cache, tok, pos, AXES, cfg)
    else:
        params = init_lm_params(key, cfg)
        cache = init_lm_cache(cfg, 1, 1, B, T)
        logits, cache2 = lm_decode_step(params, cache, tok, pos, AXES, cfg)
    assert logits.shape[0] == B
    assert jnp.all(jnp.isfinite(logits))
    nxt = tp_greedy(logits, AXES)
    assert jnp.all((nxt >= 0))
    # cache actually advanced
    changed = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert changed


def test_arch_registry_complete():
    for name in ALL_ARCHS:
        cfg = get_arch(name)
        assert cfg.source, name
    cells = runnable_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # exactly the 6 documented long_500k skips for full-attention archs
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s, _ in skipped)


def test_decode_greedy_is_deterministic():
    cfg = smoke_config(get_arch("granite-8b"))
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    tok = jnp.array([5, 7], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    outs = []
    for _ in range(2):
        cache = init_lm_cache(cfg, 1, 1, 2, 16)
        logits, _ = lm_decode_step(params, cache, tok, pos, AXES, cfg)
        outs.append(tp_greedy(logits, AXES))
    assert jnp.array_equal(outs[0], outs[1])


def test_sliding_window_masks_far_tokens():
    """SWA: a query must not attend beyond its window."""
    from repro.models import attention as A
    from repro.models.common import plan_heads

    layout = plan_heads(4, 2, 16, 1)
    key = jax.random.PRNGKey(0)
    params = A.init_attn_params(key, 32, layout)
    x = jax.random.normal(key, (1, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (1, 64))
    out_w = A.attention_train(params, x, pos, AXES, layout, window=8)
    # perturb a token far outside the window of the last query
    x2 = x.at[0, 0].add(100.0)
    out_w2 = A.attention_train(params, x2, pos, AXES, layout, window=8)
    # last position unchanged (token 0 is outside its window of 8)
    assert jnp.allclose(out_w[0, -1], out_w2[0, -1], atol=1e-4)
    # but WITHOUT the window it would change
    out_f = A.attention_train(params, x, pos, AXES, layout, window=None)
    out_f2 = A.attention_train(params, x2, pos, AXES, layout, window=None)
    assert not jnp.allclose(out_f[0, -1], out_f2[0, -1], atol=1e-4)
