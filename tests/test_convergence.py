"""Paper-claim reproduction at test scale: IntSGD converges like SGD
(Theorems 1-3 / Figure 1), Heuristic IntSGD does not, IntDIANA fixes the
heterogeneous max-int blowup (Appendix A.2 / Figure 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compressor
from repro.core.simulate import SimTrainer
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant

N = 8


def _quadratic():
    key = jax.random.PRNGKey(0)
    bs = jax.random.normal(key, (N, 20))

    def loss(params, batch):
        return 0.5 * jnp.sum((params["x"] - batch) ** 2)

    return loss, bs, {"x": jnp.zeros(20)}, bs.mean(0)


def _final_err(comp_name, steps=400, lr=0.2, momentum=0.0):
    loss, bs, x0, opt_pt = _quadratic()
    tr = SimTrainer(loss, N, make_compressor(comp_name), sgd(momentum=momentum), constant(lr))
    st = tr.init(x0)
    m = None
    for _ in range(steps):
        st, m = tr.step(st, bs)
    return float(jnp.linalg.norm(st.params["x"] - opt_pt)), m


def test_intsgd_matches_sgd_quadratic():
    """Thm 2 regime (smooth convex, deterministic grads): IntSGD reaches the
    optimum like exact SGD."""
    err_sgd, _ = _final_err("none")
    err_int, _ = _final_err("intsgd")
    err_det, _ = _final_err("intsgd_determ")
    err_blk, _ = _final_err("intsgd_block")
    assert err_sgd < 1e-5
    assert err_int < 1e-5
    assert err_det < 1e-5
    assert err_blk < 1e-5


def test_heuristic_intsgd_stalls():
    """Fig 1 phenomenon: the Sapio et al. fixed-α rule fails to reach the
    optimum that adaptive IntSGD attains."""
    err_int, _ = _final_err("intsgd")
    err_heur, _ = _final_err("heuristic_intsgd")
    assert err_heur > 100 * max(err_int, 1e-12)


def test_intsgd_with_momentum_matches_sgd_logreg():
    """Deep-learning-style setup on convex logreg (heterogeneous data):
    terminal losses match within noise (paper Table 2 accuracy parity)."""
    prob = make_logreg(jax.random.PRNGKey(1), n_workers=N, m=64, d=50)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(50)}

    def run(name):
        tr = SimTrainer(
            prob.worker_loss, N, make_compressor(name), sgd(momentum=0.9), constant(0.3)
        )
        st = tr.init(x0)
        for _ in range(250):
            st, _ = tr.step(st, data)
        return float(prob.full_loss(st.params["x"]))

    l_sgd = run("none")
    l_int = run("intsgd")
    # constant-lr noise floor allows a small gap; the paper's parity is at
    # tuned/decayed lr (Tables 2-3); 10% terminal-loss band is the analogue
    assert abs(l_int - l_sgd) / l_sgd < 0.10, (l_int, l_sgd)


def test_linear_speedup_variance_reduction():
    """Cor. 2 linear speedup ingredient: the quantization-error variance of
    the aggregate shrinks like 1/n (independent per-worker rounding)."""
    from repro.core.comm import CommCtx
    from repro.core.compressor import IntSGD
    from repro.core.scaling import AlphaState

    g = jnp.ones((64,)) * 0.37
    comp = IntSGD()

    def var_for(n):
        ctx = CommCtx(axes=("w",), axis_sizes=(n,))
        state = AlphaState(
            r=jnp.ones((n,)) * 1e-4, step=jnp.ones((n,), jnp.int32)
        )
        grads = jnp.broadcast_to(g, (n, 64))

        def worker(s, gg, key):
            ghat, _, _ = comp.aggregate(
                s, {"w": gg}, key=key, eta=jnp.float32(0.1), ctx=ctx
            )
            return ghat["w"]

        errs = []
        for t in range(50):
            out = jax.vmap(worker, in_axes=(0, 0, None), axis_name="w")(
                state, grads, jax.random.PRNGKey(t)
            )
            errs.append(np.asarray(out[0] - g))
        return np.var(np.stack(errs))

    v2, v16 = var_for(2), var_for(16)
    # α also scales with n (α ∝ 1/√n -> per-worker var ∝ n), so the net
    # aggregate variance is ~constant in n per theory; check it does NOT blow
    # up and stays within 4x across an 8x worker change
    assert v16 < 4 * v2 + 1e-12


def test_intdiana_bounds_max_int_heterogeneous():
    """Fig 6 / Appendix A.2: with heterogeneous FULL gradients (IntGD), the
    per-worker payload |Int(α g_i)|∞ blows up near the optimum because
    ||∇f_i(x*)|| ≠ 0 while ||Δx|| → 0. IntDIANA compresses g_i - h_i with
    h_i → ∇f_i(x*), keeping payload integers tiny (paper: <3 bits)."""
    from repro.core.compressor import IntSGD
    from repro.core.scaling import AlphaLastStep

    key = jax.random.PRNGKey(0)
    bs = jax.random.normal(key, (N, 30)) * 3.0  # heterogeneous optima

    def loss(p, b):
        return 0.5 * jnp.sum((p["x"] - b) ** 2)

    x0 = {"x": jnp.zeros(30)}

    def trace(comp, steps=120, lr=0.5):
        tr = SimTrainer(loss, N, comp, sgd(), constant(lr))
        st = tr.init(x0)
        out = []
        for _ in range(steps):
            st, m = tr.step(st, bs)
            out.append(0 if m is None else float(m.max_local_int))
        err = float(jnp.linalg.norm(st.params["x"] - bs.mean(0)))
        return np.asarray(out), err

    ints_gd, err_gd = trace(IntSGD(alpha_rule=AlphaLastStep()))
    ints_diana, err_diana = trace(make_compressor("intdiana"))
    # both converge to the optimum
    assert err_gd < 1e-4 and err_diana < 1e-4
    # IntGD payload explodes (>1e4); IntDIANA stays within a few bits
    assert ints_gd[-1] > 1e4, ints_gd[-1]
    assert ints_diana.max() < 64, ints_diana.max()
