"""Tables 2/3 "Computation Overhead" column analogue: wall-clock of the
compression/decompression computation per algorithm on a fixed gradient
payload, plus the Pallas fused kernels vs their unfused jnp chains.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import rounding
from repro.kernels import ops


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(emit=print):
    key = jax.random.PRNGKey(0)
    d = 1_000_000
    g = jax.random.normal(key, (d,))
    alpha = jnp.float32(1000.0)

    # IntSGD encode: scale+round+clip+cast
    enc = jax.jit(
        lambda x, k: rounding.encode(x, alpha, k, n_workers=16, bits=32)
    )
    us = _time(enc, g, key)
    emit(f"compress/intsgd_encode_jnp,{us:.0f},{d}")

    enc8 = jax.jit(
        lambda x, k: rounding.encode(x, alpha, k, n_workers=16, bits=8)
    )
    us = _time(enc8, g, key)
    emit(f"compress/intsgd_encode_int8_jnp,{us:.0f},{d}")

    encd = jax.jit(
        lambda x: rounding.encode(x, alpha, None, n_workers=16, bits=32, stochastic=False)
    )
    us = _time(encd, g)
    emit(f"compress/intsgd_encode_determ,{us:.0f},{d}")

    # Pallas kernel (interpret mode on CPU — the TPU path is the target;
    # this row validates the dispatch overhead, not TPU speed)
    usk = _time(
        lambda x, k: ops.int_compress(x, alpha, k, n_workers=16, bits=32), g, key,
        iters=3,
    )
    emit(f"compress/intsgd_encode_pallas_interp,{usk:.0f},{d}")

    # decode + fused optimizer update
    ints = enc(g, key)
    mom = jnp.zeros_like(g)
    naive = jax.jit(
        lambda s, p, m: (
            p - 0.1 * (0.9 * m + (s.astype(jnp.float32) / (16 * alpha) + 1e-4 * p)),
            0.9 * m + (s.astype(jnp.float32) / (16 * alpha) + 1e-4 * p),
        )
    )
    us = _time(naive, ints, g, mom)
    emit(f"compress/decode_update_unfused_jnp,{us:.0f},{d}")
    usk = _time(
        lambda s, p, m: ops.fused_update(s, p, m, 1.0 / (16 * alpha), 0.1, 0.9, 1e-4),
        ints, g, mom, iters=3,
    )
    emit(f"compress/decode_update_pallas_interp,{usk:.0f},{d}")

    # QSGD-style per-bucket quantization (for the overhead comparison row)
    def qsgd_enc(x, k):
        norm = jnp.linalg.norm(x) + 1e-30
        s = jnp.abs(x) / norm * 64
        lo = jnp.floor(s)
        u = jax.random.uniform(k, x.shape)
        return (lo + (u < s - lo)).astype(jnp.int8), jnp.sign(x).astype(jnp.int8), norm

    us = _time(jax.jit(qsgd_enc), g, key)
    emit(f"compress/qsgd_encode,{us:.0f},{d}")

    # NatSGD exponent rounding
    def nat_enc(x, k):
        mag = jnp.maximum(jnp.abs(x), 1e-38)
        e = jnp.floor(jnp.log2(mag))
        u = jax.random.uniform(k, x.shape)
        return (e + (u < mag / jnp.exp2(e) - 1)).astype(jnp.int8)

    us = _time(jax.jit(nat_enc), g, key)
    emit(f"compress/natsgd_encode,{us:.0f},{d}")

    # PowerSGD rank-2 compress (matrix reshaped)
    m2 = g.reshape(1000, 1000)
    q = jax.random.normal(key, (1000, 2))

    def pow_enc(mm, qq):
        p = mm @ qq
        ph, _ = jnp.linalg.qr(p)
        return ph, mm.T @ ph

    us = _time(jax.jit(pow_enc), m2, q)
    emit(f"compress/powersgd_rank2,{us:.0f},{d}")


if __name__ == "__main__":
    main()
