"""Figure 5 analogue: IntSGD sensitivity to β and ε on a heterogeneous
convex problem. CSV: name,us_per_call(terminal loss ×1e4),derived."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compressor import IntSGD
from repro.core.scaling import AlphaMovingAvg
from repro.core.simulate import SimTrainer
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant

N = 8


def main(emit=print):
    prob = make_logreg(jax.random.PRNGKey(0), n_workers=N, m=64, d=50)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(50)}

    def run(beta, eps, steps=200):
        comp = IntSGD(alpha_rule=AlphaMovingAvg(beta=beta, eps=eps))
        tr = SimTrainer(prob.worker_loss, N, comp, sgd(momentum=0.9), constant(0.3))
        st = tr.init(x0)
        for _ in range(steps):
            st, _ = tr.step(st, data)
        return float(prob.full_loss(st.params["x"]))

    for beta in [0.0, 0.3, 0.6, 0.9]:
        for eps in [1e-4, 1e-6, 1e-8]:
            loss = run(beta, eps)
            emit(f"sensitivity/beta{beta}_eps{eps:g},{loss*1e4:.1f},terminal_loss={loss:.5f}")


if __name__ == "__main__":
    main()
