"""Figure 6 analogue: per-worker payload integer |Int(α g_i)|∞ over training
for IntGD (blows up on heterogeneous data) vs IntDIANA (bounded) vs
VR-IntDIANA-style stochastic variant. CSV: name,us_per_call(max int),derived."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_compressor
from repro.core.compressor import IntSGD
from repro.core.scaling import AlphaLastStep
from repro.core.simulate import SimTrainer
from repro.optim import sgd
from repro.optim.schedules import constant

N = 8


def main(emit=print):
    key = jax.random.PRNGKey(0)
    bs = jax.random.normal(key, (N, 30)) * 3.0  # heterogeneous optima

    def loss(p, b):
        return 0.5 * jnp.sum((p["x"] - b) ** 2)

    x0 = {"x": jnp.zeros(30)}

    def trace(comp, steps=120, lr=0.5):
        tr = SimTrainer(loss, N, comp, sgd(), constant(lr))
        st = tr.init(x0)
        out = []
        for _ in range(steps):
            st, m = tr.step(st, bs)
            out.append(0 if m is None else float(m.max_local_int))
        err = float(jnp.linalg.norm(st.params["x"] - bs.mean(0)))
        return np.asarray(out), err

    for name, comp in [
        ("intgd", IntSGD(alpha_rule=AlphaLastStep())),
        ("intdiana", make_compressor("intdiana")),
    ]:
        t, err = trace(comp)
        for i in [10, 40, 80, 119]:
            emit(f"diana_maxint/{name}_step{i},{t[i]:.0f},err={err:.2e}")
        bits = 1 + np.log2(max(t[-1], 1))
        emit(f"diana_bits/{name},{bits:.1f},bits_per_coord_at_end")


if __name__ == "__main__":
    main()
