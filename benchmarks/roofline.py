"""§Roofline table generator: reads the dry-run sweep JSONLs and emits the
per-(arch × shape × mesh) three-term roofline, dominant bottleneck, model-
flops ratio and a one-line lever per cell."""
from __future__ import annotations

import json
import os
import sys

LEVERS = {
    ("compute_s", "train"): "raise MXU utilization: larger per-device batch via grad-accum, bf16 throughout",
    ("memory_s", "train"): "cut activation traffic: longer attention chunks, fewer remat boundaries, fuse optimizer (Pallas fused_update)",
    ("memory_s", "prefill"): "larger KV chunks + bf16 logits to cut per-chunk HBM rewrites",
    ("memory_s", "decode"): "KV-cache dtype (bf16->int8), batch more sequences per chip",
    ("collective_s", "train"): "shrink the gradient wire: int8 IntSGD, bucketed overlap with backward",
    ("collective_s", "prefill"): "defer TP psums across fused layers / sequence-sharded activations",
    ("collective_s", "decode"): "replicate small weights to drop TP psums at batch=1",
}


def load(path):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "roofline" in r:
                rows.append(r)
    return rows


def table(rows, emit=print):
    emit(
        f"| {'arch':21s} | {'shape':11s} | chips | {'compute_s':>10s} | {'memory_s':>10s} "
        f"| {'coll_s':>9s} | dominant | {'6ND/HLO':>7s} | arg_GB | tmp_GB |"
    )
    emit("|" + "-" * 21 + "|" + "-" * 13 + "|-------|" + "-" * 12 + "|" + "-" * 12 + "|" + "-" * 11 + "|----------|" + "-" * 9 + "|--------|--------|")
    for r in rows:
        t = r["roofline"]
        kind = "train" if r["shape"].startswith("train") else (
            "prefill" if "prefill" in r["shape"] else "decode")
        emit(
            f"| {r['arch']:21s} | {r['shape']:11s} | {r['n_chips']:5d} "
            f"| {t['compute_s']:10.3e} | {t['memory_s']:10.3e} | {t['collective_s']:9.2e} "
            f"| {r['dominant'].replace('_s',''):8s} | {r['useful_flops_frac']:7.3f} "
            f"| {r['memory']['argument_bytes']/1e9:6.2f} | {r['memory']['temp_bytes']/1e9:6.2f} |"
        )


def main(emit=print):
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name in ("dryrun_single_pod_v2.jsonl", "dryrun_multi_pod_v2.jsonl"):
        rows = load(os.path.join(here, name))
        if rows:
            emit(f"\n== {name} ==")
            table(rows, emit)


if __name__ == "__main__":
    main()
