# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  bench_convergence       — Figure 1 (IntSGD vs Heuristic vs SGD curves)
  bench_compress_overhead — Tables 2/3 computation-overhead column
  bench_comm_volume       — Tables 2/3 communication column (structural bytes)
  bench_sensitivity       — Figure 5 (β/ε sweep)
  bench_diana             — Figure 6 (max transmitted integer, IntGD vs DIANA)
  roofline                — §Roofline table from the dry-run sweeps (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--only name]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_comm_volume,
        bench_compress_overhead,
        bench_convergence,
        bench_diana,
        bench_sensitivity,
        roofline,
    )

    suites = {
        "compress_overhead": bench_compress_overhead.main,
        "diana": bench_diana.main,
        "sensitivity": bench_sensitivity.main,
        "convergence": bench_convergence.main,
        "comm_volume": bench_comm_volume.main,
        "roofline": roofline.main,
    }
    failures = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
