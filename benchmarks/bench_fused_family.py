"""The fused decode+update family, measured structurally and on the clock.

PR 4 extended the Pallas fused route from (IntSGD × momentum-SGD) to the
full capability matrix — {sgd, adamw} optimizer kernels × {dense, packed}
codecs × {IntSGD, IntDIANA} compressors. This bench builds the fused train
step for each (optimizer × codec) pair and reports, from the jaxpr of the
built step (benchmarks.jaxpr_cost):

  * ``n_pallas_calls`` — fused kernel launches per step (one per param
    leaf: decode + moment update + apply in a single HBM pass each);
  * ``image_hbm_roundtrips`` — int32 inputs of INTEGER-IMAGE size entering
    a Pallas kernel. On the packed codec the kernels must consume the d/k
    transport words directly (in-register unpack), so this is 0: the
    summed integer image never makes an HBM round-trip between the
    all-reduce and the parameters. A nonzero count means someone unpacked
    outside the kernel;
  * ``bytes_fused`` / ``dp_int_bytes`` / ``flops`` — the jaxpr_cost
    structural totals (post-fusion HBM-byte estimate, integer dp collective
    bytes, FLOPs);
  * ``step_ms`` — measured wall-clock per compressed step (CPU interpret
    mode; relative across rows only, the TARGET is TPU Mosaic).

``--check`` asserts the headline HBM-pass property: the fused AdamW route
performs NO MORE integer-image HBM round-trips than fused SGD — i.e. zero
on the packed codec — and launches the same number of fused kernels (the
extra moment tensor rides the same pass, not an extra one). Wired into CI
next to the bench_comm_volume / bench_overlap smokes. Artifact:
``BENCH_fused_family.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json, time
sys.path.insert(0, r"%(repo)s/src")
sys.path.insert(0, r"%(repo)s")
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.inputs import materialize_batch
from repro.launch.step import build_train_step, build_init_state
from repro.models.transformer import init_lm_params
from repro.optim import adamw, sgd
from repro.optim.schedules import constant
from benchmarks.jaxpr_cost import analyze, summarize, iter_eqns

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cfg = smoke_config(get_arch("granite-8b"))
key = jax.random.PRNGKey(0)

def pallas_stats(jaxpr):
    calls = 0
    image_roundtrips = 0
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        calls += 1
        f32_out = [v.aval for v in eqn.outvars
                   if str(v.aval.dtype) == "float32"]
        if not f32_out:
            continue
        image = max(int(np.prod(a.shape)) for a in f32_out)
        for v in eqn.invars:
            a = getattr(v, "aval", None)
            if a is None or not hasattr(a, "shape"):
                continue
            # image-sized int32 into the kernel = the decoded integer image
            # took an HBM round-trip; packed transport words are image/k
            if str(a.dtype) == "int32" and int(np.prod(a.shape)) > image // 2:
                image_roundtrips += 1
    return calls, image_roundtrips

def measure(opt_name, comp_name, wire_name):
    comp = make_compressor(comp_name, bits=8)
    opt = {"sgd": sgd(momentum=0.9, weight_decay=1e-4),
           "adamw": adamw()}[opt_name]
    art = build_train_step(
        cfg, mesh, shape, compressor=comp, base_opt=opt,
        lr_schedule=constant(0.01), param_dtype=jnp.float32,
        fused=True, donate=False, wire=wire_name,
    )
    fn = art.jitted["compressed"]
    closed = jax.make_jaxpr(fn)(*art.arg_structs)
    calls, rt = pallas_stats(closed.jaxpr)
    s = summarize(analyze(fn, *art.arg_structs))
    params = init_lm_params(key, cfg, tp=2, n_shards=1, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    init = build_init_state(cfg, mesh, compressor=comp, base_opt=opt,
                            fused=True)
    opt_state, comp_state = init(params)
    batch = materialize_batch(cfg, shape, key)
    args = lambda i: (params, opt_state, comp_state, jnp.int32(i),
                      jax.random.fold_in(key, i), batch)
    jax.block_until_ready(fn(*args(0)))  # compile + warm
    t0 = time.time()
    reps = 2
    for i in range(1, 1 + reps):
        out = fn(*args(i))
    jax.block_until_ready(out)
    return {
        "n_pallas_calls": calls,
        "image_hbm_roundtrips": rt,
        "bytes_fused": s["bytes_fused"],
        "dp_int_bytes": s["dp_int_bytes"],
        "flops": s["flops"],
        "step_ms": (time.time() - t0) / reps * 1e3,
    }

rows = {}
for opt_name in ("sgd", "adamw"):
    for wire_name in ("dense8", "packed8"):
        rows[f"{opt_name}+intsgd8+{wire_name}"] = measure(
            opt_name, "intsgd", wire_name)
rows["adamw+intdiana+packed8"] = measure("adamw", "intdiana", "packed8")
print("RESULT " + json.dumps(rows))
"""


def main(emit=print, check: bool = False):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD % {"repo": repo}],
        capture_output=True, text=True, timeout=1800, env=env, cwd=repo,
    )
    if r.returncode != 0:
        emit(f"bench_fused_family/ERROR,0,{r.stderr[-300:]!r}")
        if check:
            raise SystemExit(1)
        return
    rows = None
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    if rows is None:
        emit("bench_fused_family/ERROR,0,'no RESULT line'")
        if check:
            raise SystemExit(1)
        return

    artifact = {
        "mesh": {"data": 2, "model": 2},
        "arch": "granite-8b (smoke)",
        "rows": rows,
    }
    with open(os.path.join(repo, "BENCH_fused_family.json"), "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)

    for name, row in rows.items():
        emit(
            f"fused_family/{name},{row['step_ms']:.1f},"
            f"pallas={row['n_pallas_calls']}"
            f";image_rt={row['image_hbm_roundtrips']}"
            f";bytes_fused={row['bytes_fused']:.3e}"
            f";dp_int_bytes={row['dp_int_bytes']:.0f}"
        )

    if check:
        failures = []
        sgd_row = rows["sgd+intsgd8+packed8"]
        adamw_row = rows["adamw+intsgd8+packed8"]
        if adamw_row["image_hbm_roundtrips"] > sgd_row["image_hbm_roundtrips"]:
            failures.append(
                "fused AdamW makes more integer-image HBM round-trips than "
                f"fused SGD: {adamw_row['image_hbm_roundtrips']} > "
                f"{sgd_row['image_hbm_roundtrips']}"
            )
        for name in ("sgd+intsgd8+packed8", "adamw+intsgd8+packed8",
                     "adamw+intdiana+packed8"):
            if rows[name]["image_hbm_roundtrips"] != 0:
                failures.append(
                    f"{name}: packed fused route let the integer image "
                    f"round-trip HBM {rows[name]['image_hbm_roundtrips']}×; "
                    "the kernels must consume transport words in-register"
                )
        if adamw_row["n_pallas_calls"] != sgd_row["n_pallas_calls"]:
            failures.append(
                "fused AdamW launches a different kernel count than fused "
                f"SGD ({adamw_row['n_pallas_calls']} vs "
                f"{sgd_row['n_pallas_calls']}): the extra moment tensor "
                "must ride the same pass, not an extra launch"
            )
        if failures:
            emit(f"fused_family/CHECK_FAILED,0,{failures!r}")
            raise SystemExit(1)
        emit(
            "fused_family/CHECK_OK,1,adamw fused route: zero integer-image "
            "HBM round-trips, same kernel-launch count as sgd"
        )


if __name__ == "__main__":
    main(check="--check" in sys.argv[1:])
