"""Overlap-readiness of the wire transport, measured structurally.

The serial route puts ONE monolithic integer psum on the critical path —
nothing for XLA's latency-hiding scheduler to work with. The bucketed ring
route (``overlap="ring"``) cuts the same transport words into fixed-size
buckets and reduces each with an independent ppermute ring + chunk
all-gather: many small collectives whose hops can interleave with whatever
compute is still pending (the next microbatch's backward, the unpack of the
previous bucket). This bench counts exactly that, from the jaxpr of the
built train step:

  * the serial route emits exactly 1 integer dp collective;
  * the bucketed route emits >= 2 (one ring per bucket — the interleavable
    units);
  * the per-worker wire PAYLOAD is unchanged: the bucket manifest's bytes
    equal the serial route's integer dp psum bytes, and both equal the
    ``packed8`` dp_int row of BENCH_comm_volume.json (bucketing is slicing
    bookkeeping, not re-encoding — zero byte inflation).

Since PR 9 the runtime counts are no longer the only evidence: each route
also carries a STATIC column derived by :mod:`repro.analysis.schedule` /
``traffic`` from the spec alone — declared collective count/bytes
(``BucketManifest.ring_collectives`` must agree) and the static roofline
fractions (``hidden``/``interleavable``). ``--check`` asserts
static == measured per route, and pins the fresh static counts against the
COMMITTED ``BENCH_overlap.json`` (12 bucketed vs 1 serial on this debug
mesh), so a transport change must regenerate the artifact explicitly.

Artifact: ``BENCH_overlap.json`` at the repo root, the PR 2 JSON pattern.
Runs in a subprocess with 4 forced host devices on the same (2 data x 2
model) debug mesh as bench_comm_volume, so the byte comparison is
apples-to-apples.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, r"%(repo)s/src")
sys.path.insert(0, r"%(repo)s")
import jax, jax.numpy as jnp
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step, resolve_layout
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.wire import PackedInt, plan_buckets
from repro.analysis import schedule as schedule_mod
from benchmarks.jaxpr_cost import analyze, summarize, _axes_of, iter_eqns

BUCKET_WORDS = 4096
mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cfg = smoke_config(get_arch("granite-8b"))

def count_int_dp_collectives(jaxpr):
    # interleavable integer collectives on the data-parallel axes: the
    # units XLA's scheduler can overlap with pending compute
    out = {}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in ("psum", "ppermute", "all_gather"):
            continue
        axes = _axes_of(eqn)
        if axes == ("model",):
            continue
        if not any(
            hasattr(v, "aval")
            and jnp.issubdtype(jnp.dtype(v.aval.dtype), jnp.integer)
            for v in eqn.invars
        ):
            continue
        out[name] = out.get(name, 0) + 1
    return out

def measure(overlap):
    comp = make_compressor("intsgd8", wire=PackedInt(bits=8))
    art = build_train_step(
        cfg, mesh, shape, compressor=comp, base_opt=sgd(momentum=0.9),
        lr_schedule=constant(0.1), overlap=overlap,
        bucket_words=BUCKET_WORDS,
    )
    fn = art.jitted["compressed"]
    closed = jax.make_jaxpr(fn)(*art.arg_structs)
    counts = count_int_dp_collectives(closed.jaxpr)
    s = summarize(analyze(fn, *art.arg_structs))
    # the static column: same trace, but counts/bytes DERIVED from the
    # declared transport model + the dependence-graph roofline (PR 9)
    rep = schedule_mod.full_audit(closed, art.audit_spec)
    plan = rep.traffic.plan
    return {
        "collective_eqns": counts,
        "n_int_dp_collectives": sum(counts.values()),
        "dp_int_bytes": s["dp_int_bytes"],
        "dp_bytes": s["dp_bytes"],
        "static": {
            "declared_eqns": plan.n_eqns,
            "declared_bytes": plan.coll_bytes,
            "observed_eqns": rep.traffic.observed_eqns,
            "n_serialized": rep.schedule.n_serialized,
            "hidden_fraction": round(rep.schedule.hidden_fraction, 6),
            "interleavable_fraction": round(
                rep.schedule.interleavable_fraction, 6
            ),
            "ok": rep.ok,
            "rules": sorted({v.rule for v in rep.violations}),
        },
    }

serial = measure("off")
bucketed = measure("ring")

# the bucket manifest: payload bytes of the SAME words tree, bucketed
layout = resolve_layout(cfg, mesh)
wf = PackedInt(bits=8)
n = layout.n_dp
words_struct = jax.eval_shape(
    lambda t: jax.tree.map(lambda v: wf.pack(v, n_workers=n), t),
    layout.l_shapes,
)
manifest = plan_buckets(words_struct, bucket_words=BUCKET_WORDS)
bucketed["n_buckets"] = manifest.n_buckets
bucketed["manifest_bytes"] = manifest.payload_bytes
bucketed["bucket_words"] = BUCKET_WORDS
ring_eqns, ring_bytes = manifest.ring_collectives(
    tuple(mesh.shape[a] for a in ("data",))
)
bucketed["manifest_ring_eqns"] = ring_eqns
bucketed["manifest_ring_bytes"] = ring_bytes
print("RESULT " + json.dumps({"serial": serial, "bucketed": bucketed}))
"""


def main(emit=print, check: bool = False):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD % {"repo": repo}],
        capture_output=True, text=True, timeout=1800, env=env, cwd=repo,
    )
    if r.returncode != 0:
        emit(f"bench_overlap/ERROR,0,{r.stderr[-300:]!r}")
        if check:
            raise SystemExit(1)
        return
    out = None
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            out = json.loads(line[len("RESULT "):])
    if out is None:
        emit("bench_overlap/ERROR,0,'no RESULT line'")
        if check:
            raise SystemExit(1)
        return

    serial, bucketed = out["serial"], out["bucketed"]
    artifact_path = os.path.join(repo, "BENCH_overlap.json")
    committed = None
    if os.path.exists(artifact_path):
        with open(artifact_path) as f:
            committed = json.load(f)
    artifact = {
        "mesh": {"data": 2, "model": 2},
        "arch": "granite-8b (smoke)",
        "codec": "packed8",
        "serial": serial,
        "bucketed": bucketed,
    }
    with open(artifact_path, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)

    emit(
        f"overlap/serial,{serial['n_int_dp_collectives']},"
        f"dp_int_bytes={serial['dp_int_bytes']:.0f}"
        f";eqns={serial['collective_eqns']}"
        f";static_eqns={serial['static']['declared_eqns']}"
        f";hidden={serial['static']['hidden_fraction']}"
        f";inter={serial['static']['interleavable_fraction']}"
    )
    emit(
        f"overlap/bucketed,{bucketed['n_int_dp_collectives']},"
        f"buckets={bucketed['n_buckets']}"
        f";manifest_bytes={bucketed['manifest_bytes']}"
        f";eqns={bucketed['collective_eqns']}"
        f";static_eqns={bucketed['static']['declared_eqns']}"
        f";hidden={bucketed['static']['hidden_fraction']}"
        f";inter={bucketed['static']['interleavable_fraction']}"
    )

    if check:
        failures = []
        if serial["n_int_dp_collectives"] != 1:
            failures.append(
                f"serial route should put ONE monolithic integer psum on the "
                f"wire, found {serial['collective_eqns']}"
            )
        if bucketed["n_buckets"] < 2:
            failures.append(
                f"bucketed route produced {bucketed['n_buckets']} bucket(s); "
                "nothing to interleave"
            )
        if bucketed["n_int_dp_collectives"] < 2:
            failures.append(
                f"bucketed route emitted {bucketed['n_int_dp_collectives']} "
                "integer dp collectives; expected >= 2 interleavable units"
            )
        if bucketed["manifest_bytes"] != serial["dp_int_bytes"]:
            failures.append(
                f"bucketing changed the per-worker wire payload: manifest "
                f"{bucketed['manifest_bytes']} B vs serial psum "
                f"{serial['dp_int_bytes']:.0f} B"
            )
        # static == measured, per route: the analyzer's declared transport
        # must land on exactly the collectives the jaxpr counter sees
        for name, route in (("serial", serial), ("bucketed", bucketed)):
            st = route.get("static") or {}
            if st.get("declared_eqns") != route["n_int_dp_collectives"]:
                failures.append(
                    f"{name} route: static transport model declares "
                    f"{st.get('declared_eqns')} wire collective(s) but the "
                    f"jaxpr counter measured {route['n_int_dp_collectives']}"
                )
            if not st.get("ok", False):
                failures.append(
                    f"{name} route: static audit not clean: {st.get('rules')}"
                )
        if bucketed["manifest_ring_eqns"] != bucketed["n_int_dp_collectives"]:
            failures.append(
                f"BucketManifest.ring_collectives declares "
                f"{bucketed['manifest_ring_eqns']} eqn(s) but the jaxpr "
                f"counter measured {bucketed['n_int_dp_collectives']}"
            )
        if bucketed["static"]["interleavable_fraction"] != 1.0:
            failures.append(
                f"bucketed route's static roofline says only "
                f"{bucketed['static']['interleavable_fraction']} of wire "
                f"bytes are interleavable; the bucketed ring promises 1.0"
            )
        # committed-artifact gate: fresh STATIC counts must match the
        # committed measured counts (12 bucketed vs 1 serial on this mesh)
        if committed is not None:
            for name, route in (("serial", serial), ("bucketed", bucketed)):
                was = (committed.get(name) or {}).get("n_int_dp_collectives")
                now = route["static"]["declared_eqns"]
                if was is not None and was != now:
                    failures.append(
                        f"{name} route: static count {now} drifted from the "
                        f"committed BENCH_overlap.json count {was} — a "
                        f"transport change must regenerate the artifact "
                        f"explicitly"
                    )
        ref_path = os.path.join(repo, "BENCH_comm_volume.json")
        if os.path.exists(ref_path):
            with open(ref_path) as f:
                ref = json.load(f)
            ref_bytes = ref.get("codecs", {}).get("packed8", {}).get("dp_int")
            if ref_bytes is not None and bucketed["manifest_bytes"] != ref_bytes:
                failures.append(
                    f"per-step wire bytes drifted vs BENCH_comm_volume.json: "
                    f"{bucketed['manifest_bytes']} != packed8 dp_int "
                    f"{ref_bytes:.0f}"
                )
        if failures:
            emit(f"overlap/CHECK_FAILED,0,{failures!r}")
            raise SystemExit(1)
        emit("overlap/CHECK_OK,1,bucketed route interleavable at unchanged bytes")


if __name__ == "__main__":
    main(check="--check" in sys.argv[1:])
