"""Figure 1 analogue: IntSGD (random/determ, int8/int32) vs Heuristic IntSGD
vs full-precision SGD — training curves on a small causal LM (synthetic
corpus) with the paper's optimizer (SGD + momentum 0.9 + wd 1e-4).

Emits CSV rows: algo,step,loss and a terminal-quality summary.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.core import make_compressor
from repro.core.simulate import SimTrainer
from repro.data.synthetic import SyntheticLMData, worker_batches
from repro.models.common import Axes
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import sgd
from repro.optim.schedules import constant

N_WORKERS = 4
STEPS = 60


def main(emit=print):
    cfg = smoke_config(get_arch("granite-8b"))
    axes = Axes()
    data = SyntheticLMData(cfg.vocab, seq_len=32, batch_per_worker=4, seed=0)
    params0 = init_lm_params(jax.random.PRNGKey(0), cfg)

    def loss_fn(params, batch):
        return lm_loss(params, batch, axes, cfg)

    algos = {
        "sgd": "none",
        "intsgd_random_32": "intsgd",
        "intsgd_determ_32": "intsgd_determ",
        "intsgd_random_8": "intsgd8",
        "heuristic_int8": "heuristic_intsgd",
    }
    finals = {}
    for algo, comp in algos.items():
        tr = SimTrainer(
            loss_fn, N_WORKERS, make_compressor(comp), sgd(momentum=0.9, weight_decay=1e-4),
            constant(0.5),
        )
        st = tr.init(params0)
        t0 = time.time()
        for i in range(STEPS):
            st, m = tr.step(st, worker_batches(data, i, N_WORKERS))
            if i % 10 == 0 or i == STEPS - 1:
                lv = float(loss_fn(st.params, data.batch(10_000, 0)))
                emit(f"bench_convergence/{algo},{i},{lv:.4f}")
        finals[algo] = lv
        emit(f"bench_convergence_final/{algo},{(time.time()-t0)*1e6/STEPS:.0f},{lv:.4f}")
    # the paper's headline: adaptive IntSGD matches SGD; heuristic int8 gaps
    gap_int = finals["intsgd_random_32"] - finals["sgd"]
    gap_heu = finals["heuristic_int8"] - finals["sgd"]
    emit(f"bench_convergence_gap/intsgd_vs_sgd,{0},{gap_int:.4f}")
    emit(f"bench_convergence_gap/heuristic_vs_sgd,{0},{gap_heu:.4f}")


if __name__ == "__main__":
    main()
