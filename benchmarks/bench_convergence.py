"""Figure 1 analogue: IntSGD (random/determ, int8/int32) vs Heuristic IntSGD
vs full-precision SGD — training curves on a small causal LM (synthetic
corpus) with the paper's optimizer (SGD + momentum 0.9 + wd 1e-4).

Also the sparse-wire matched-loss evidence (ROADMAP open item 1): on the
logreg recipe, intsgd8 over the topk8:64 gather wire reaches packed8's
final loss — with 4× fewer dp wire bytes PER STEP (d=1280: 320 B of
idx+vals planes vs 1280 B of packed words). Error feedback pays for the
dropped coordinates in STEPS, not in accuracy: the bench reports the step
multiple honestly (the sparse wire trades wall-clock for wire bytes, the
right trade exactly when the interconnect is the bottleneck).

Emits CSV rows: algo,step,loss and a terminal-quality summary.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.core import make_compressor
from repro.core.simulate import SimTrainer
from repro.data.synthetic import SyntheticLMData, worker_batches
from repro.models.common import Axes
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import sgd
from repro.optim.schedules import constant

N_WORKERS = 4
STEPS = 60


def main(emit=print):
    cfg = smoke_config(get_arch("granite-8b"))
    axes = Axes()
    data = SyntheticLMData(cfg.vocab, seq_len=32, batch_per_worker=4, seed=0)
    params0 = init_lm_params(jax.random.PRNGKey(0), cfg)

    def loss_fn(params, batch):
        return lm_loss(params, batch, axes, cfg)

    algos = {
        "sgd": "none",
        "intsgd_random_32": "intsgd",
        "intsgd_determ_32": "intsgd_determ",
        "intsgd_random_8": "intsgd8",
        "heuristic_int8": "heuristic_intsgd",
    }
    finals = {}
    for algo, comp in algos.items():
        tr = SimTrainer(
            loss_fn, N_WORKERS, make_compressor(comp), sgd(momentum=0.9, weight_decay=1e-4),
            constant(0.5),
        )
        st = tr.init(params0)
        t0 = time.time()
        for i in range(STEPS):
            st, m = tr.step(st, worker_batches(data, i, N_WORKERS))
            if i % 10 == 0 or i == STEPS - 1:
                lv = float(loss_fn(st.params, data.batch(10_000, 0)))
                emit(f"bench_convergence/{algo},{i},{lv:.4f}")
        finals[algo] = lv
        emit(f"bench_convergence_final/{algo},{(time.time()-t0)*1e6/STEPS:.0f},{lv:.4f}")
    # the paper's headline: adaptive IntSGD matches SGD; heuristic int8 gaps
    gap_int = finals["intsgd_random_32"] - finals["sgd"]
    gap_heu = finals["heuristic_int8"] - finals["sgd"]
    emit(f"bench_convergence_gap/intsgd_vs_sgd,{0},{gap_int:.4f}")
    emit(f"bench_convergence_gap/heuristic_vs_sgd,{0},{gap_heu:.4f}")
    logreg_topk_matched_loss(emit)


def logreg_topk_matched_loss(emit=print):
    """Sparse wire on the logreg recipe: run packed8 to its final loss,
    then run topk8:64 until it matches — report the step multiple and the
    per-step dp wire-byte ratio (4× at d=1280)."""
    from repro.data.logreg import make_logreg

    n, d = 8, 1280
    prob = make_logreg(jax.random.PRNGKey(0), n_workers=n, m=64, d=d)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(d)}

    def trainer(comp):
        return SimTrainer(
            prob.worker_loss, n, comp, sgd(momentum=0.9), constant(0.3)
        )

    # dense reference: packed8 for 1000 steps
    tr = trainer(make_compressor("intsgd", bits=8, wire="packed8"))
    st = tr.init(x0)
    for _ in range(1000):
        st, _ = tr.step(st, data)
    target = float(prob.full_loss(st.params["x"]))
    emit(f"bench_convergence_logreg/packed8,{1000},{target:.5f}")

    # sparse wire: same optimizer, run until the final loss matches (EF
    # trades steps for bytes; the budget caps the trade at 14x)
    tr = trainer(make_compressor("intsgd", bits=8, wire="topk8:64"))
    st = tr.init(x0)
    steps, matched = 0, False
    while steps < 14_000:
        for _ in range(500):
            st, _ = tr.step(st, data)
        steps += 500
        loss = float(prob.full_loss(st.params["x"]))
        if loss <= target:
            matched = True
            break
    emit(f"bench_convergence_logreg/topk8_64,{steps},{loss:.5f}")

    from repro.wire import make_wire_format

    bytes_packed = make_wire_format("packed8").wire_bytes(d)
    bytes_topk = make_wire_format("topk8:64").wire_bytes(d)
    ratio = bytes_packed / bytes_topk
    emit(
        f"bench_convergence_logreg/matched,{int(matched)},"
        f"wire_bytes_per_step_ratio={ratio:.2f}x"
        f";steps_multiple={steps / 1000:.1f}x"
    )


if __name__ == "__main__":
    main()
