"""Exact structural cost model: walk the jaxpr, multiply through scan trip
counts, and account FLOPs + unfused bytes + per-collective bytes.

Why not compiled.cost_analysis()? XLA counts a while-loop body ONCE — with
scan-over-layers every per-layer matmul, byte and collective is undercounted
by ~n_layers ×. This walker multiplies by scan `length`, giving the true
per-device totals the roofline needs. (EXPERIMENTS.md §Dry-run reports both
and the ratio.)

Collectives are tagged by mesh axis so the table separates
  * TP bytes  (psum over "model" — activation reductions),
  * DP bytes  (psum over ("pod","data") — the gradient wire IntSGD shrinks;
    reported per-dtype so int8/int32 vs f32 is visible).

FLOP conventions: dot_general = 2·M·N·K·batch; elementwise = 1/output elem;
reductions = input size. Bytes = operands+outputs per eqn (unfused upper
bound; fusion on TPU lowers the true HBM traffic — the roofline memory term
is therefore conservative, consistently across §Perf iterations).

The generic jaxpr iteration layer lives in
:mod:`repro.analysis.jaxpr_walk` (promoted there in PR 8 so the wire
auditor shares it); this module re-exports ``iter_eqns``/``_axes_of``/
``_COLLECTIVES`` for the benchmarks that import them from here and keeps
only the COST semantics.
"""
from __future__ import annotations

import math
from collections import defaultdict

import jax

from repro.analysis.jaxpr_walk import (
    CALL_PRIMS as _CALL_PRIMS,  # noqa: F401  (bench imports)
    COLLECTIVES as _COLLECTIVES,
    aval_nelem as _nelem,
    aval_size_bytes as _size_bytes,
    eqn_axes as _axes_of,
    iter_eqns,
)


class Cost:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0  # unfused upper bound (every eqn's operands+outputs)
        self.bytes_fused = 0.0  # post-fusion estimate: only matmuls, gathers,
        # scatters, scan boundaries and collectives touch HBM; elementwise
        # chains fuse into their producers on TPU
        self.coll = defaultdict(float)  # (kind, axes, dtype) -> bytes

    def scaled(self, k):
        c = Cost()
        c.flops = self.flops * k
        c.bytes = self.bytes * k
        c.bytes_fused = self.bytes_fused * k
        for key, v in self.coll.items():
            c.coll[key] = v * k
        return c

    def add(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.bytes_fused += other.bytes_fused
        for key, v in other.coll.items():
            self.coll[key] += v


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(
        s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb)
    )
    n = math.prod(
        s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb)
    )
    return 2.0 * batch * m * n * contract


def jaxpr_cost(jaxpr) -> Cost:
    cost = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        # ---- recursion into sub-jaxprs ----
        if name == "scan":
            inner = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            cost.add(inner.scaled(eqn.params["length"]))
            continue
        if name == "while":
            # no unbounded whiles in this codebase; count once
            cost.add(jaxpr_cost(eqn.params["body_jaxpr"].jaxpr))
            continue
        if name == "cond":
            branches = [jaxpr_cost(b.jaxpr) for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c.flops + c.bytes, default=None)
            if worst:
                cost.add(worst)
            continue
        if name == "shard_map":
            cost.add(jaxpr_cost(eqn.params["jaxpr"]))
            continue
        sub = None
        for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if k in eqn.params:
                sub = eqn.params[k]
                break
        if sub is not None:
            cost.add(jaxpr_cost(sub.jaxpr if hasattr(sub, "jaxpr") else sub))
            continue

        # ---- collectives ----
        if name in _COLLECTIVES:
            kind = _COLLECTIVES[name]
            axes = _axes_of(eqn)
            for v in eqn.invars:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    b = _size_bytes(v.aval)
                    cost.coll[(kind, axes, str(v.aval.dtype))] += b
                    cost.bytes += 2 * b  # read + write through HBM
                    cost.bytes_fused += 2 * b
            continue

        # ---- compute ----
        out_elems = sum(_nelem(v.aval) for v in eqn.outvars if hasattr(v, "aval"))
        in_bytes = sum(
            _size_bytes(v.aval)
            for v in eqn.invars
            if hasattr(v, "aval") and hasattr(v.aval, "shape")
        )
        out_bytes = sum(
            _size_bytes(v.aval) for v in eqn.outvars if hasattr(v, "aval")
        )
        if name == "dot_general":
            cost.flops += _dot_flops(eqn)
            cost.bytes_fused += in_bytes + out_bytes
        elif name in ("gather", "scatter", "scatter_add", "dynamic_slice",
                      "dynamic_update_slice", "sort", "top_k", "iota"):
            cost.bytes_fused += in_bytes + out_bytes
        elif name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "argmax", "argmin", "cumsum", "cumlogsumexp"):
            cost.flops += sum(
                _nelem(v.aval) for v in eqn.invars if hasattr(v, "aval")
            )
        elif name in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                      "sin", "cos", "pow", "integer_pow", "div", "add", "sub",
                      "mul", "max", "min", "select_n", "floor", "round",
                      "clamp", "sign", "and", "or", "xor", "shift_right_logical",
                      "shift_left", "lt", "le", "gt", "ge", "eq", "ne",
                      "convert_element_type", "neg", "abs", "log1p", "expm1"):
            cost.flops += out_elems
        cost.bytes += in_bytes + out_bytes
    return cost


def analyze(fn, *args):
    """Trace fn abstractly and return the structural Cost (per device if fn
    is a shard_map'd step on local shapes; the caller passes global jit fn —
    shard_map bodies see local shapes, so the walk is per-device)."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed.jaxpr)


def summarize(cost: Cost) -> dict:
    by_kind = defaultdict(float)
    tp_bytes = 0.0
    dp_bytes = 0.0
    dp_int_bytes = 0.0
    for (kind, axes, dtype), b in cost.coll.items():
        by_kind[kind] += b
        if axes == ("model",):
            tp_bytes += b
        else:
            dp_bytes += b
            if dtype.startswith("int") or dtype.startswith("uint"):
                dp_int_bytes += b
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "bytes_fused": cost.bytes_fused,
        "collective_bytes": float(sum(by_kind.values())),
        "coll_by_kind": dict(by_kind),
        "tp_bytes": tp_bytes,
        "dp_bytes": dp_bytes,
        "dp_int_bytes": dp_int_bytes,
    }
