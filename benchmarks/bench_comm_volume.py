"""Tables 2/3 "Communication" column analogue, measured structurally: the
per-device collective bytes each algorithm's train step puts on the wire,
from the jaxpr cost walker on a (2 data × 2 model) debug mesh.

This is the CPU-only stand-in for the paper's wall-clock comparison: on
fixed hardware, all-reduce-able int8 beats all-reduce f32 beats all-gather —
the BYTES ordering here is exactly the paper's TIME ordering.

Two tables:
  * per-CODEC rows (the wire subsystem): f32 baseline vs DenseInt lanes vs
    PackedInt transport words, unfused and fused routes — the table that
    proves the bit-packed wire actually shrinks the data-parallel collective
    (dp_int column), not just the dtype bookkeeping;
  * per-COMPRESSOR rows (the paper's baselines) for continuity.

Artifacts: emits CSV rows (name,us_per_call,derived — us_per_call carries
dp_bytes, derived the breakdown) AND writes ``BENCH_comm_volume.json`` at
the repo root. ``--check`` asserts the codec compression ratios so CI can
smoke the table (see .github/workflows/ci.yml):

    dp_int(packed8)      <= dp_int(dense32) / 2   (is 4x: 1 vs 4 B/coord)
    dp_int(packed4)      <= dp_int(dense8)  / 2   (2x: sub-lane packing)
    dp(packed8_fused)    <= dp(dense32)     / 2   (the int8-packed recipe
        end to end vs the default transport; is 5x. Vs the int8 lane +
        ZeRO-1 route it is 2x-epsilon — the epsilon being 16 bytes of
        scalar metric psums — reported but not asserted.)
    dp_int(topk8_64)     <= dp_int(packed8) / 4   (the sparse gather
        payload: 64 idx+vals pairs per leaf vs a dense word per 4 coords —
        convergence at matched final loss is bench_convergence's logreg
        section)

Runs itself in a subprocess with 4 forced host devices so the parent
process' single-device view is untouched.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, os.path.join(os.path.dirname(r"%(repo)s"), "%(repo_tail)s", "src"))
sys.path.insert(0, r"%(repo)s/src")
sys.path.insert(0, r"%(repo)s")
import jax, jax.numpy as jnp
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step
from repro.optim import sgd
from repro.optim.schedules import constant
from benchmarks.jaxpr_cost import analyze, summarize

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cfg = smoke_config(get_arch("granite-8b"))

def measure(comp, fused=False):
    art = build_train_step(cfg, mesh, shape, compressor=comp,
                           base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1),
                           fused=fused)
    s = summarize(analyze(art.jitted["compressed"], *art.arg_structs))
    return {"dp": s["dp_bytes"], "tp": s["tp_bytes"],
            "total": s["collective_bytes"], "dp_int": s["dp_int_bytes"]}

codecs = {
    "f32": ("none", None, False),
    "dense32": ("intsgd", None, False),
    "dense8": ("intsgd8", None, False),
    "dense4": ("intsgd4", None, False),
    "packed8": ("intsgd8", "packed8", False),
    "packed4": ("intsgd4", "packed4", False),
    "dense8_fused": ("intsgd8", None, True),
    "packed8_fused": ("intsgd8", "packed8", True),
    "topk8_64": ("intsgd8", "topk8:64", False),
}
out = {"codecs": {}, "compressors": {}}
for row, (name, wire, fused) in codecs.items():
    kw = {"wire": wire} if wire else {}
    out["codecs"][row] = measure(make_compressor(name, **kw), fused=fused)
for name in ["none", "allgather_sgd", "intsgd", "intsgd8", "heuristic_intsgd",
             "powersgd", "signsgd", "qsgd", "natsgd", "intdiana"]:
    out["compressors"][name] = measure(make_compressor(name))
print("RESULT " + json.dumps(out))
"""


def _ratios(codecs: dict) -> dict:
    div = lambda a, b: a / max(b, 1.0)
    return {
        "packed8_vs_dense32_dp_int": div(
            codecs["dense32"]["dp_int"], codecs["packed8"]["dp_int"]
        ),
        "packed4_vs_dense8_dp_int": div(
            codecs["dense8"]["dp_int"], codecs["packed4"]["dp_int"]
        ),
        "packed8_fused_vs_dense32_dp": div(
            codecs["dense32"]["dp"], codecs["packed8_fused"]["dp"]
        ),
        "packed8_fused_vs_dense8_dp": div(
            codecs["dense8"]["dp"], codecs["packed8_fused"]["dp"]
        ),
        "dense8_vs_f32_dp_int": div(
            codecs["f32"]["dp"], codecs["dense8"]["dp_int"]
        ),
        "topk8_64_vs_packed8_dp_int": div(
            codecs["packed8"]["dp_int"], codecs["topk8_64"]["dp_int"]
        ),
    }


def main(emit=print, check: bool = False):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    code = _CHILD % {"repo": repo, "repo_tail": os.path.basename(repo)}
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1800, env=env, cwd=repo,
    )
    if r.returncode != 0:
        emit(f"bench_comm_volume/ERROR,0,{r.stderr[-300:]!r}")
        if check:
            raise SystemExit(1)
        return
    out = None
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            out = json.loads(line[len("RESULT "):])
    if out is None:
        emit("bench_comm_volume/ERROR,0,'no RESULT line'")
        if check:
            raise SystemExit(1)
        return

    ratios = _ratios(out["codecs"])
    artifact = {
        "mesh": {"data": 2, "model": 2},
        "arch": "granite-8b (smoke)",
        "codecs": out["codecs"],
        "compressors": out["compressors"],
        "ratios": ratios,
    }
    with open(os.path.join(repo, "BENCH_comm_volume.json"), "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)

    for row, v in out["codecs"].items():
        emit(
            f"comm_volume/codec_{row},{v['dp']:.0f},total={v['total']:.0f}"
            f";dp_int={v['dp_int']:.0f}"
        )
    base = out["compressors"]["none"]["dp"]
    for name, v in out["compressors"].items():
        ratio = base / max(v["dp"], 1)
        emit(
            f"comm_volume/{name},{v['dp']:.0f},total={v['total']:.0f}"
            f";dp_int={v['dp_int']:.0f};dp_compression_vs_sgd={ratio:.2f}x"
        )
    for k, v in ratios.items():
        emit(f"comm_volume/ratio_{k},{v:.2f},")

    if check:
        failures = [
            k
            for k in (
                "packed8_vs_dense32_dp_int",
                "packed4_vs_dense8_dp_int",
                "packed8_fused_vs_dense32_dp",
            )
            if ratios[k] < 2.0
        ]
        # the sparse-wire headline (ROADMAP open item 1): the top-64 gather
        # payload beats packed8's dense words by >= 4x on the dp wire
        if ratios["topk8_64_vs_packed8_dp_int"] < 4.0:
            failures.append("topk8_64_vs_packed8_dp_int")
        if failures:
            emit(f"comm_volume/CHECK_FAILED,0,{failures!r}")
            raise SystemExit(1)
        emit("comm_volume/CHECK_OK,1,all codec ratios hold "
             "(packed >= 2x, topk >= 4x)")


if __name__ == "__main__":
    main(check="--check" in sys.argv[1:])
