"""Tables 2/3 "Communication" column analogue, measured structurally: the
per-device collective bytes each algorithm's train step puts on the wire,
from the jaxpr cost walker on a (2 data × 2 model) debug mesh.

This is the CPU-only stand-in for the paper's wall-clock comparison: on
fixed hardware, all-reduce-able int8 beats all-reduce f32 beats all-gather —
the BYTES ordering here is exactly the paper's TIME ordering.

Runs itself in a subprocess with 4 forced host devices so the parent
process' single-device view is untouched.  CSV: name,us_per_call,derived
(us_per_call column carries dp_bytes; derived carries total collective
bytes).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, os.path.join(os.path.dirname(r"%(repo)s"), "%(repo_tail)s", "src"))
sys.path.insert(0, r"%(repo)s/src")
sys.path.insert(0, r"%(repo)s")
import jax, jax.numpy as jnp
from repro.configs import get_arch, smoke_config, ShapeConfig
from repro.core import make_compressor
from repro.launch.step import build_train_step
from repro.optim import sgd
from repro.optim.schedules import constant
from benchmarks.jaxpr_cost import analyze, summarize

mesh = jax.make_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cfg = smoke_config(get_arch("granite-8b"))
out = {}
for name in ["none", "allgather_sgd", "intsgd", "intsgd8", "heuristic_intsgd",
             "powersgd", "signsgd", "qsgd", "natsgd", "intdiana"]:
    art = build_train_step(cfg, mesh, shape, compressor=make_compressor(name),
                           base_opt=sgd(momentum=0.9), lr_schedule=constant(0.1))
    s = summarize(analyze(art.jitted["compressed"], *art.arg_structs))
    out[name] = {"dp": s["dp_bytes"], "tp": s["tp_bytes"],
                 "total": s["collective_bytes"], "dp_int": s["dp_int_bytes"]}
print("RESULT " + json.dumps(out))
"""


def main(emit=print):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    code = _CHILD % {"repo": repo, "repo_tail": os.path.basename(repo)}
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env=env, cwd=repo,
    )
    if r.returncode != 0:
        emit(f"bench_comm_volume/ERROR,0,{r.stderr[-200:]!r}")
        return
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            out = json.loads(line[len("RESULT "):])
            base = out["none"]["dp"]
            for name, v in out.items():
                ratio = base / max(v["dp"], 1)
                emit(
                    f"comm_volume/{name},{v['dp']:.0f},total={v['total']:.0f}"
                    f";dp_int={v['dp_int']:.0f};dp_compression_vs_sgd={ratio:.2f}x"
                )


if __name__ == "__main__":
    main()
