"""Batched serving demo: continuous batching over slots with a smoke-scale
GQA model — greedy decode, slot reuse, deterministic outputs.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_arch, smoke_config
from repro.models.transformer import init_lm_params
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = smoke_config(get_arch("granite-8b"))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=4, max_seq=128)
    prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [21], [31, 32], [41, 42, 43]]
    reqs = [Request(rid=i, prompt=p, max_new=12) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    iters = eng.run()
    dt = time.time() - t0
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out}")
    total = sum(len(r.out) for r in reqs)
    print(f"\n{total} tokens over {len(reqs)} requests in {iters} engine "
          f"iterations ({total/dt:.1f} tok/s on CPU; 4-slot continuous batching)")


if __name__ == "__main__":
    main()
