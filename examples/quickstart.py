"""Quickstart: train a small causal LM end-to-end with IntSGD (the paper's
algorithm) and watch the integer wire statistics alongside the loss.

  PYTHONPATH=src python examples/quickstart.py [--steps 200] [--big]

--big uses a ~100M-parameter config (xlstm-125m at full width, reduced
depth); the default is a fast ~3M-param model so the example completes in a
couple of minutes on one CPU core. Both run the REAL distributed step
(shard_map on a 1x1 mesh) — the identical code the dry-run lowers for 512
chips.
"""
import argparse
import dataclasses
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import CheckpointStore
from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--compressor", default="intsgd")
    ap.add_argument("--ckpt-dir", default="/tmp/intsgd_quickstart")
    args = ap.parse_args()

    if args.big:
        cfg = dataclasses.replace(
            get_arch("xlstm-125m"), n_layers=3, name="xlstm-100m-quickstart"
        )
        shape = ShapeConfig("quickstart", 128, 8, "train")
    else:
        cfg = smoke_config(get_arch("granite-8b"))
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=2048)
        shape = ShapeConfig("quickstart", 64, 8, "train")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    store = CheckpointStore(args.ckpt_dir, keep_last=2)
    _, losses = train_loop(
        cfg, mesh, shape,
        compressor=args.compressor, steps=args.steps, lr=0.4,
        ckpt=store, ckpt_every=50, log_every=10,
    )
    print(f"\nfinal loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
