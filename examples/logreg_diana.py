"""Appendix C.5 reproduction: l2-regularized logistic regression across 12
heterogeneous workers — IntGD's per-worker payload integers blow up near the
optimum; IntDIANA (GD and L-SVRG-flavoured stochastic estimators) keeps them
within ~3 bits while converging at the same rate.

  PYTHONPATH=src python examples/logreg_diana.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_compressor
from repro.core.compressor import IntSGD
from repro.core.scaling import AlphaLastStep
from repro.core.simulate import SimTrainer
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant

N = 12


def main():
    # strong convexity (λ=0.1) so full-gradient descent contracts fast —
    # the regime where ||Δx||→0 exposes IntGD's payload blowup (Fig. 6)
    prob = make_logreg(
        jax.random.PRNGKey(0), n_workers=N, m=128, d=300, lam=1e-1,
        heterogeneity=2.0,
    )
    # normalize features so L = O(1) and full GD contracts at lr=1 — the
    # fast-contraction regime where ||Δx||→0 exposes the payload blowup
    import dataclasses as _dc
    prob = _dc.replace(prob, A=prob.A / jnp.sqrt(300.0))
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(300)}

    def run(name, comp, steps=800, lr=1.0):
        tr = SimTrainer(prob.worker_loss, N, comp, sgd(), constant(lr))
        st = tr.init(x0)
        ints, losses = [], []
        for i in range(steps):
            st, m = tr.step(st, data)
            ints.append(0 if m is None else float(m.max_local_int))
            if i % 50 == 0 or i == steps - 1:
                losses.append(float(prob.full_loss(st.params["x"])))
        print(f"{name:10s} loss: " + " ".join(f"{l:.4f}" for l in losses))
        marks = [10, 100, 300, 500, steps - 1]
        print(f"{name:10s} |payload|∞: " + " ".join(f"@{i}:{ints[i]:.0f}" for i in marks))
        bits = 1 + np.log2(max(ints[-1], 1))
        print(f"{name:10s} -> {bits:.1f} bits/coordinate at the end\n")

    print("== IntGD (full gradients, Prop-3 α) — the blowup ==")
    run("intgd", IntSGD(alpha_rule=AlphaLastStep()))
    print("== IntDIANA (gradient differences) — bounded ==")
    run("intdiana", make_compressor("intdiana"))


if __name__ == "__main__":
    main()
