"""Fault-tolerance demo: train on 8 workers, checkpoint, kill 2 workers,
re-mesh and resume with n=6 — IntSGD's α rule absorbs the worker-count
change because n is an input of the scaling formula.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.core import make_compressor
from repro.core.simulate import SimTrainer
from repro.data.logreg import make_logreg
from repro.optim import sgd
from repro.optim.schedules import constant
from repro.runtime import plan_after_failures


def main():
    prob = make_logreg(jax.random.PRNGKey(0), n_workers=8, m=64, d=40)
    data = prob.worker_data()
    x0 = {"x": jnp.zeros(40)}
    ckpt = CheckpointStore(tempfile.mkdtemp(prefix="intsgd_elastic_"))

    tr = SimTrainer(prob.worker_loss, 8, make_compressor("intsgd"), sgd(), constant(0.4))
    st = tr.init(x0)
    for i in range(40):
        st, _ = tr.step(st, data)
    ckpt.save(40, {"params": st.params}); ckpt.wait()
    print(f"step 40 (n=8): loss {float(prob.full_loss(st.params['x'])):.5f} — checkpointed")

    # --- simulate losing devices 12..15 and 20..23 (dp replicas 6,7 at tp=2)
    plan = plan_after_failures(dp=8, tp=2, failed_devices=[12, 15, 21], global_batch=64)
    print(f"failure plan: retire replicas {plan.retired_replicas}, continue with n_dp={plan.n_dp}")
    print(f"  policy: {plan.note}")

    got, _, step = ckpt.restore({"params": x0})
    tr2 = SimTrainer(prob.worker_loss, plan.n_dp, make_compressor("intsgd"), sgd(), constant(0.4))
    st2 = tr2.init(got["params"])
    surv = jax.tree.map(lambda x: x[: plan.n_dp], data)
    for i in range(40):
        st2, m = tr2.step(st2, surv)
    print(f"step 80 (n={plan.n_dp}): loss {float(prob.full_loss(st2.params['x'])):.5f} "
          f"— training continued through the failure (max wire int {float(m.max_int):.0f})")


if __name__ == "__main__":
    main()
