"""Learning-rate schedules. All return f(step:int32 array) -> lr (f32)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def step_decay(lr: float, boundaries, factor: float = 0.1):
    """Paper's ResNet schedule: decay by `factor` at each boundary epoch/step."""
    bs = jnp.asarray(boundaries, jnp.int32)

    def f(step):
        k = jnp.sum((step >= bs).astype(jnp.float32))
        return lr * (factor**k)

    return f


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return f


def warmup_wrap(sched, warmup_steps: int):
    """Linear warmup (Goyal et al. 2017 scaling rule, used in the paper)."""

    def f(step):
        warm = sched(jnp.zeros((), jnp.int32)) * (
            step.astype(jnp.float32) + 1.0
        ) / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, sched(step))

    return f
