"""ZeRO-1: optimizer state (f32 master weights + moments) sharded over the
data-parallel axes; bf16 compute params replicated.

This is what makes the 32B-class configs fit 16 GB/chip: per device the
footprint is bf16_params/TP + 2·f32_state/(TP·DP) instead of
3·f32_params/TP.

Storage layout per parameter leaf (LOCAL TP shard flattened and padded):
    master, moments: (n_dp, k_loc/n_dp)   — global (n_dp, tp·k_loc/n_dp),
                                            PartitionSpec (dp_axes, "model")

Step protocol (inside shard_map):
    1. ĝ (decoded IntSGD aggregate, identical on all dp members) is reshaped
       to (n_dp, k/n_dp) and each member takes ITS row;
    2. the base optimizer update runs on the f32 shard;
    3. the new bf16 shard is all-gathered over dp → full new params.
The all-gather is bf16 (half the bytes of the f32 gradient it replaces in a
ZeRO-less design) and is the only extra collective ZeRO-1 introduces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim.base import Optimizer
from repro.parallel import collectives as coll


def _pad_rows(flat, n_dp):
    k = flat.shape[0]
    per = (k + n_dp - 1) // n_dp
    return jnp.pad(flat, (0, per * n_dp - k)).reshape(n_dp, per)


def shard_leaf(x, n_dp):
    """param leaf -> (n_dp, k/n_dp) f32 master layout."""
    return _pad_rows(x.reshape(-1).astype(jnp.float32), n_dp)


def zero1_init(base: Optimizer, params, n_dp: int):
    masters = jax.tree.map(lambda p: shard_leaf(p, n_dp), params)
    return {"master": masters, "base": base.init(masters)}


def zero1_update(
    base: Optimizer,
    state,
    ghat,
    eta,
    *,
    dp_axes: Tuple[str, ...],
    dp_index,
    n_dp: int,
    param_dtype=jnp.bfloat16,
    params_like=None,
):
    """Returns (new_params, new_state). Runs INSIDE shard_map.

    state leaves carry a leading local dp dim of 1 (the device's own shard
    row); ghat is the full local-TP gradient tree."""
    masters = state["master"]

    def own_row(leaf):  # (1, k) local -> (k,); scalars (adam count) pass through
        return leaf[0] if leaf.ndim >= 2 else leaf

    g_rows = jax.tree.map(
        lambda g, m: lax.dynamic_slice_in_dim(
            _pad_rows(g.reshape(-1).astype(jnp.float32), n_dp), dp_index, 1, 0
        )[0],
        ghat,
        masters,
    )
    m_rows = jax.tree.map(own_row, masters)
    b_rows = jax.tree.map(own_row, state["base"])
    updates, new_base = base.update(g_rows, b_rows, m_rows, eta)
    new_master = jax.tree.map(lambda m, u: m + u, m_rows, updates)

    def gather_param(mrow, p_like):
        full = coll.all_gather_flat(mrow.astype(param_dtype), dp_axes, n_dp)
        return full.reshape(-1)[: p_like.size].reshape(p_like.shape)

    new_params = jax.tree.map(gather_param, new_master, params_like)
    restack = lambda t: jax.tree.map(lambda x: x[None] if x.ndim >= 1 else x, t)
    return new_params, {"master": restack(new_master), "base": restack(new_base)}


def zero1_state_specs(state_shapes, dp_spec, model_axis="model"):
    """PartitionSpecs for a zero1 state tree (from eval_shape shapes).
    model_axis=None (tp==1 axis-remap mode): dim1 replicated."""
    from jax.sharding import PartitionSpec as P

    def spec(leaf):
        if leaf.ndim >= 2:
            return P(dp_spec, model_axis) if model_axis else P(dp_spec, None)
        return P()

    return jax.tree.map(spec, state_shapes)
