"""Minimal optimizer interface (optax-style, no external deps).

An Optimizer is a pair of pure functions::

    init(params)                    -> opt_state
    update(grads, opt_state, params, lr) -> (updates, opt_state)

``updates`` are *added* to params (they already include the -lr factor). The
learning rate is threaded explicitly because IntSGD's α rule needs η_k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[..., tuple]  # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Gradient clipping wrapper (applied to the aggregated gradient)."""
    import jax.numpy as jnp

    from repro.utils.tree import tree_sq_norm

    def update(grads, state, params, lr):
        gn = jnp.sqrt(tree_sq_norm(grads))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params, lr)

    return Optimizer(init=opt.init, update=update)
