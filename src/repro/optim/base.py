"""Minimal optimizer interface (optax-style, no external deps).

An Optimizer is a pair of pure functions::

    init(params)                    -> opt_state
    update(grads, opt_state, params, lr) -> (updates, opt_state)

``updates`` are *added* to params (they already include the -lr factor). The
learning rate is threaded explicitly because IntSGD's α rule needs η_k.

``dx_scale`` converts the *applied* update Δx into the gradient-equivalent
displacement the IntSGD α rules are analyzed for (paper §4.1): with heavy-
ball momentum μ the steady-state update is amplified by 1/(1-μ) relative to
η·g, and the quantization noise it injects into x is amplified by the same
factor — so the α rule must see (1-μ)·||Δx||, i.e. dx_scale = 1-μ. The same
EMA amplification applies to Adam's first moment (m = b1·m + (1-b1)·g with
the update reading m, not (1-b1)·g): dx_scale = 1-b1. Only genuinely
memoryless rules (plain SGD) use 1.0. Trainers multiply the DxStats fed to
``Compressor.observe_update`` by dx_scale² (see stats.scale_dx_stats).

``fused_kernel`` is the optimizer half of the fused-route capability
contract (the compressor half is ``Compressor.fused_capable``): the name of
the Pallas fused decode+update kernel this update rule can ride ("sgd" |
"adamw"), or None when the rule has no fused form (nesterov, custom
wrappers). ``launch.step`` routes (codec × optimizer) pairs on these two
capabilities — it never inspects concrete types. The per-kernel state layout
and scalar schedule live HERE (``FUSED_STATE_TENSORS`` and friends) so the
step builder and the wire codecs stay kernel-agnostic; the kernels
themselves live in :mod:`repro.kernels.fused_update`.

``kind``/``hyper`` expose the update rule's identity for logging and for
the fused-scalar packing below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[..., tuple]  # (grads, state, params, lr) -> (updates, state)
    dx_scale: float = 1.0  # applied-update -> gradient-equivalent factor
    kind: str = "custom"  # "sgd" | "adamw" | "custom"
    hyper: Optional[Mapping[str, Any]] = None  # static hyperparameters
    fused_kernel: Optional[str] = None  # fused decode+update kernel capability


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Gradient clipping wrapper (applied to the aggregated gradient).

    The wrapped update is opaque, so the fused capability does not survive
    the chain (use build_train_step(clip_norm=...) on the fused route — the
    clip factor is folded into the kernel's scalar vector there)."""
    import jax.numpy as jnp

    from repro.utils.tree import tree_sq_norm

    def update(grads, state, params, lr):
        gn = jnp.sqrt(tree_sq_norm(grads))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params, lr)

    return dataclasses.replace(opt, update=update, kind="custom",
                               fused_kernel=None)


# ---------------------------------------------------------------------------
# fused-kernel registry: state layout + scalar schedule per kernel name.
# ONE enumeration, consumed by launch/step.py (state shapes/specs/init) and
# by the kernel scalar packing — the wire codecs dispatch on the name only.
# ---------------------------------------------------------------------------
# per-param f32 state tensors each kernel reads AND writes, in the order the
# kernel's refs (and its returned tuple) use
FUSED_STATE_TENSORS = {"sgd": ("mom",), "adamw": ("mu", "nu")}
# replicated scalar state carried outside the kernels
FUSED_STATE_SCALARS = {"sgd": (), "adamw": ("count",)}
# shared scalar tail appended after the per-leaf [inv_nalpha, clip] header;
# see kernels/fused_update.py for the canonical vectors. omb1/omb2 are
# (1-b1)/(1-b2) PRE-ROUNDED from the python-float hyperparameters so the
# kernels multiply by the exact same f32 constants as optim/adamw.py's
# ``(1 - b1) * g`` — recomputing 1-b1 in f32 inside the kernel is one ULP
# off, which the bf16 forward amplifies past any ULP-parity tolerance.
FUSED_SCALAR_TAIL = {
    "sgd": ("lr", "mu", "wd"),
    "adamw": ("lr", "b1", "omb1", "b2", "omb2", "eps", "wd", "bc1", "bc2"),
}


def fused_state_init(opt: Optimizer, params):
    """Zero-initialized fused-route optimizer state for ``opt.fused_kernel``
    (replicated f32 tensors per param + scalar counters)."""
    kern = opt.fused_kernel
    if kern is None:
        raise ValueError(
            f"optimizer kind={opt.kind!r} exposes no fused kernel "
            "(Optimizer.fused_kernel is None)"
        )
    state = {
        name: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        for name in FUSED_STATE_TENSORS[kern]
    }
    for name in FUSED_STATE_SCALARS[kern]:
        state[name] = jnp.zeros((), jnp.int32)
    return state


def fused_step_scalars(opt: Optimizer, opt_state, eta):
    """One step of the kernel's shared scalar tail (everything after the
    per-leaf [inv_nalpha, clip] header) plus the advanced scalar state.

    Returns ``(tail, new_scalars)`` where ``tail`` is a tuple of f32 scalars
    in ``FUSED_SCALAR_TAIL[kernel]`` order and ``new_scalars`` maps the
    ``FUSED_STATE_SCALARS`` entries to their post-step values."""
    kern = opt.fused_kernel
    h = opt.hyper or {}
    if kern == "sgd":
        return (eta, jnp.float32(h["momentum"]),
                jnp.float32(h["weight_decay"])), {}
    if kern == "adamw":
        b1, b2 = float(h["b1"]), float(h["b2"])
        count = opt_state["count"] + 1
        t = count.astype(jnp.float32)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        return (
            eta, jnp.float32(b1), jnp.float32(1.0 - b1), jnp.float32(b2),
            jnp.float32(1.0 - b2), jnp.float32(h["eps"]),
            jnp.float32(h["weight_decay"]), bc1, bc2,
        ), {"count": count}
    raise ValueError(f"unknown fused kernel {kern!r}")


def fused_reference_update(opt: Optimizer, ghat, params, opt_state, eta):
    """Unfused reference of the fused kernels' arithmetic, on full trees.

    Used by the exact (step-0) path of the fused route — which has a decoded
    float aggregate and no integer payload — and by the kernel property
    tests. Bit-compatible with the kernels up to FMA reassociation."""
    kern = opt.fused_kernel
    tail, new_scalars = fused_step_scalars(opt, opt_state, eta)
    if kern == "sgd":
        lr, mu, wd = tail

        def leaf(p, m, g):
            p32 = p.astype(jnp.float32)
            g32 = g.astype(jnp.float32) + wd * p32
            m32 = mu * m.astype(jnp.float32) + g32
            return (p32 - lr * m32).astype(p.dtype), m32

        outs = jax.tree.map(leaf, params, opt_state["mom"], ghat)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        new_params = jax.tree.map(lambda o: o[0], outs, is_leaf=is_pair)
        new_mom = jax.tree.map(lambda o: o[1], outs, is_leaf=is_pair)
        return new_params, {"mom": new_mom}
    if kern == "adamw":
        lr, b1, omb1, b2, omb2, eps, wd, bc1, bc2 = tail

        def leaf(p, m, v, g):
            p32 = p.astype(jnp.float32)
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + omb1 * g32
            v32 = b2 * v.astype(jnp.float32) + omb2 * jnp.square(g32)
            step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            return (p32 - lr * (step + wd * p32)).astype(p.dtype), m32, v32

        outs = jax.tree.map(
            leaf, params, opt_state["mu"], opt_state["nu"], ghat
        )
        is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
        new_params = jax.tree.map(lambda o: o[0], outs, is_leaf=is_triple)
        new_mu = jax.tree.map(lambda o: o[1], outs, is_leaf=is_triple)
        new_nu = jax.tree.map(lambda o: o[2], outs, is_leaf=is_triple)
        return new_params, dict(mu=new_mu, nu=new_nu, **new_scalars)
    raise ValueError(f"unknown fused kernel {kern!r}")
