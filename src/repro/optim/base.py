"""Minimal optimizer interface (optax-style, no external deps).

An Optimizer is a pair of pure functions::

    init(params)                    -> opt_state
    update(grads, opt_state, params, lr) -> (updates, opt_state)

``updates`` are *added* to params (they already include the -lr factor). The
learning rate is threaded explicitly because IntSGD's α rule needs η_k.

``dx_scale`` converts the *applied* update Δx into the gradient-equivalent
displacement the IntSGD α rules are analyzed for (paper §4.1): with heavy-
ball momentum μ the steady-state update is amplified by 1/(1-μ) relative to
η·g, and the quantization noise it injects into x is amplified by the same
factor — so the α rule must see (1-μ)·||Δx||, i.e. dx_scale = 1-μ. Plain
SGD and scale-free optimizers (Adam) use 1.0. Trainers multiply the DxStats
fed to ``Compressor.observe_update`` by dx_scale² (see stats.scale_dx_stats).

``kind``/``hyper`` expose the update rule's identity to the step-builder
pipeline so it can route onto fused kernels (kernels/ops.fused_update needs
(momentum, weight_decay) of a plain SGD rule to fuse decode+update).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

import jax

OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[..., tuple]  # (grads, state, params, lr) -> (updates, state)
    dx_scale: float = 1.0  # applied-update -> gradient-equivalent factor
    kind: str = "custom"  # "sgd" | "adamw" | "custom" (fused-kernel routing)
    hyper: Optional[Mapping[str, Any]] = None  # static hyperparameters


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Gradient clipping wrapper (applied to the aggregated gradient)."""
    import jax.numpy as jnp

    from repro.utils.tree import tree_sq_norm

    def update(grads, state, params, lr):
        gn = jnp.sqrt(tree_sq_norm(grads))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params, lr)

    return dataclasses.replace(opt, update=update, kind="custom")
