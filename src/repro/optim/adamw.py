"""AdamW — the production optimizer for the LM-family configs.

IntSGD composes with any server-side optimizer: the compression happens on
the raw stochastic gradient (the quantity that crosses the wire); Adam moments
are computed from the decoded aggregate on every worker identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        t = count.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["nu"], grads
        )
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(
        init=init,
        update=update,
        kind="adamw",
        hyper=dict(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay),
    )
