"""AdamW — the production optimizer for the LM-family configs.

IntSGD composes with any server-side optimizer: the compression happens on
the raw stochastic gradient (the quantity that crosses the wire), and the
moment state depends on the gradient history only through the decoded
aggregate. The invariant the routes maintain is that the (mu, nu, count)
state is bit-identical across update routes — computed from the full
decoded aggregate on the ZeRO-1 path (each worker holding its own dp
shard rows of it) and from the in-register decode on the fused Pallas
path, never from local pre-aggregation gradients (pinned by the fused vs
unfused moment-parity tests in tests/test_distributed.py).

§4.1 correction: the first moment is an EMA (m = b1·m + (1-b1)·g) whose
steady state carries the full gradient, so quantization noise injected into
the applied update is amplified by 1/(1-b1) exactly as heavy-ball momentum
amplifies it by 1/(1-μ) — hence ``dx_scale = 1-b1``, converting the
observed ||Δx|| back to the gradient-equivalent units the α rules are
analyzed for (see optim.base; regression-pinned in tests/test_compressors.py
alongside the SGD-momentum mirror in tests/test_scaling.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        t = count.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["nu"], grads
        )
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(
        init=init,
        update=update,
        dx_scale=1.0 - b1,  # §4.1: the m-EMA amplifies injected noise 1/(1-b1)
        kind="adamw",
        hyper=dict(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay),
        fused_kernel="adamw",
    )
