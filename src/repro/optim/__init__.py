from repro.optim.base import OptState, Optimizer, apply_updates
from repro.optim.sgd import sgd
from repro.optim.adamw import adamw
from repro.optim.schedules import constant, cosine_decay, step_decay, warmup_wrap
