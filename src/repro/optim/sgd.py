"""SGD with momentum + weight decay, matching torch.optim.SGD semantics
(the optimizer used in the paper's deep-learning experiments).

Weight decay is added to the (aggregated, decompressed) gradient *before*
momentum, as in PyTorch. Momentum buffer: m = μ m + g;  update = -lr * m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd(momentum: float = 0.0, weight_decay: float = 0.0, nesterov: bool = False):
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32), grads, params
            )
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            eff = jax.tree.map(lambda g, m: g + momentum * m, grads, new_m)
        else:
            eff = new_m
        return jax.tree.map(lambda m: -lr * m, eff), new_m

    # momentum amplifies the applied update (and the injected quantization
    # noise) by 1/(1-μ) at steady state; the α rule sees (1-μ)²||Δx||².
    return Optimizer(
        init=init,
        update=update,
        dx_scale=1.0 - momentum,
        kind="sgd",
        hyper=dict(momentum=momentum, weight_decay=weight_decay, nesterov=nesterov),
        # the Pallas decode+momentum-SGD kernel implements the heavy-ball
        # form only; nesterov has no fused route
        fused_kernel=None if nesterov else "sgd",
    )
