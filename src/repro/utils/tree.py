"""Pytree helpers used across the framework.

All helpers are jit-safe (pure jnp) and operate on arbitrary pytrees of
arrays. The flatten/unflatten pair gives the "one big vector" view of a model
that the IntSGD theory is written in (x ∈ R^d), while the rest of the
framework keeps the structured per-layer view.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_dot(a, b):
    """<a, b> over all leaves, returned as a scalar."""
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return jnp.sum(jnp.stack([jnp.asarray(l, jnp.float32) for l in leaves]))


def tree_sq_norm(a):
    """||a||^2 over all leaves (float32 accumulation)."""
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    )
    return jnp.sum(jnp.stack(leaves))


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_size(a) -> int:
    """Total number of scalar entries d (static python int)."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(a)))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def flatten_to_vector(tree):
    """Concatenate all leaves into one 1-D vector. Returns (vec, unflatten_fn)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(v):
        out, off = [], 0
        for shape, size in zip(shapes, sizes):
            out.append(v[off : off + size].reshape(shape))
            off += size
        return jax.tree.unflatten(treedef, out)

    return vec, unflatten


def unflatten_from_vector(vec, like):
    """Reshape a flat vector back into the structure of `like`."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        size = int(np.prod(l.shape))
        out.append(vec[off : off + size].reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def tree_abs_max(a):
    """max |leaf value| over all leaves, as f32 (wire-width metrics)."""
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x: jnp.max(jnp.abs(x).astype(jnp.float32)), a)
    )
    return jnp.max(jnp.stack(leaves))
