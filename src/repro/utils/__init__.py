from repro.utils.tree import (
    tree_dot,
    tree_sq_norm,
    tree_scale,
    tree_add,
    tree_sub,
    tree_zeros_like,
    tree_size,
    flatten_to_vector,
    unflatten_from_vector,
    tree_cast,
)
