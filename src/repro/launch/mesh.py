"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device; only
dryrun.py sets the 512-placeholder-device XLA flag before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    """The data-parallel (gradient-sync) axes: everything except `model`."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_sizes_of(mesh) -> tuple[int, ...]:
    return tuple(mesh.shape[a] for a in dp_axes_of(mesh))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device CPU tests (spawned with forced host
    device count in a subprocess)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
