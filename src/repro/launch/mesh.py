"""Production mesh construction.

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device; only
dryrun.py sets the 512-placeholder-device XLA flag before first jax use.

The axis-name helpers (dp_axes_of, dp_sizes_of) live in
:mod:`repro.parallel.collectives`, the version-portable collectives layer.
"""
from __future__ import annotations

from repro.parallel.collectives import mesh_from_counts


def make_production_mesh(*, multi_pod: bool = False):
    if multi_pod:
        return mesh_from_counts(pod=2, data=16, model=16)
    return mesh_from_counts(data=16, model=16)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device CPU tests (spawned with forced host
    device count in a subprocess)."""
    return mesh_from_counts(data=n_data, model=n_model)
