"""Training driver: end-to-end loop with checkpointing, fault tolerance and
elastic re-mesh.

CLI (CPU-scale demo; the same builder lowers for the production mesh in
dryrun.py):

  PYTHONPATH=src python -m repro.launch.train \\
      --arch granite-8b --smoke --steps 50 --compressor intsgd \\
      --ckpt-dir /tmp/ckpt [--resume] [--data 2 --model 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import ShapeConfig, get_arch, smoke_config
from repro.core import make_compressor, with_wire
from repro.data.synthetic import SyntheticLMData
from repro.launch.step import build_init_state, build_train_step
from repro.models.transformer import init_lm_params
from repro.optim import adamw, sgd
from repro.optim.schedules import constant, warmup_wrap
from repro.parallel.collectives import mesh_from_counts
from repro.wire import wire_format_names
from repro.wire.bucketing import DEFAULT_BUCKET_WORDS


def train_loop(
    cfg,
    mesh,
    shape,
    *,
    compressor,
    steps: int,
    lr: float = 0.3,
    ckpt: CheckpointStore | None = None,
    ckpt_every: int = 20,
    resume: bool = False,
    param_dtype=jnp.float32,
    log_every: int = 5,
    seed: int = 0,
    fused: bool = False,
    clip_norm: float | None = 1.0,
    wire: str | None = None,
    overlap: str = "off",
    bucket_words: int = DEFAULT_BUCKET_WORDS,
    microbatches: int = 1,
    opt: str = "sgd",
):
    comp = make_compressor(compressor)
    if wire is not None:
        comp = with_wire(comp, wire)
    opts = {
        "sgd": lambda: sgd(momentum=0.9, weight_decay=1e-4),
        "adamw": lambda: adamw(weight_decay=1e-4),
    }
    opt = opts[opt]()
    sched = warmup_wrap(constant(lr), 5)
    art = build_train_step(
        cfg, mesh, shape, compressor=comp, base_opt=opt,
        lr_schedule=sched, param_dtype=param_dtype,
        fused=fused, clip_norm=clip_norm,
        overlap=overlap, bucket_words=bucket_words, microbatches=microbatches,
    )
    tp = mesh.shape["model"]
    n_dp = mesh.size // tp
    key = jax.random.PRNGKey(seed)

    start = 0
    if resume and ckpt and ckpt.latest_step() is not None:
        structs = {"params": art.arg_structs[0], "opt": art.arg_structs[1],
                   "comp": art.arg_structs[2]}
        shardings = {"params": art.in_shardings[0], "opt": art.in_shardings[1],
                     "comp": art.in_shardings[2]}
        state, extra, start = ckpt.restore(structs, shardings=shardings)
        params, opt_state, comp_state = state["params"], state["opt"], state["comp"]
        print(f"[train] resumed from step {start}")
    else:
        params = init_lm_params(key, cfg, tp=tp, n_shards=1, dtype=param_dtype)
        params = jax.device_put(params, art.in_shardings[0])
        init = build_init_state(
            cfg, mesh, compressor=comp, base_opt=opt, fused=fused
        )
        opt_state, comp_state = init(params)

    data = SyntheticLMData(
        cfg.vocab, shape.seq_len, shape.global_batch, seed=seed
    )
    batch_sharding = art.in_shardings[5]

    losses = []
    for i in range(start, steps):
        batch = data.batch(i, 0)  # global batch; sharded by device_put
        batch = {k: jax.device_put(v, batch_sharding[k]) for k, v in batch.items()}
        fn = art.jitted["exact"] if i == 0 else art.jitted["compressed"]
        t0 = time.time()
        params, opt_state, comp_state, loss, metrics = fn(
            params, opt_state, comp_state, jnp.int32(i), jax.random.fold_in(key, i), batch
        )
        if i % log_every == 0 or i == steps - 1:
            print(
                f"[train] step {i:5d} loss {float(loss):.4f} "
                f"max_int {float(metrics[0]):.0f} bits {float(metrics[1]):.0f} "
                f"dt {time.time()-t0:.2f}s"
            )
        losses.append(float(loss))
        if ckpt and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state, "comp": comp_state})
    if ckpt:
        ckpt.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--compressor", default="intsgd")
    ap.add_argument("--opt", default="sgd", choices=["sgd", "adamw"],
                    help="base optimizer; both ride the fused Pallas "
                         "decode+update route under --fused")
    ap.add_argument("--wire", default=None,
                    help="wire codec for the integer gradient transport: "
                         + ", ".join(wire_format_names()))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--fused", action="store_true",
                    help="route the update through the Pallas fused "
                         "dequantize+SGD kernel")
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--overlap", default="off", choices=["off", "ring"],
                    help="wire transport: 'off' = one monolithic integer "
                         "psum; 'ring' = bucketed ppermute ring all-reduce "
                         "XLA overlaps with backward compute (bit-identical "
                         "result)")
    ap.add_argument("--bucket-words", type=int, default=DEFAULT_BUCKET_WORDS,
                    help="transport words per overlap bucket")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="grad-accum microbatches; with --overlap ring, "
                         "microbatch i's wire reduce runs behind microbatch "
                         "i+1's backward")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = mesh_from_counts(data=args.data, model=args.model)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ckpt = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    train_loop(
        cfg, mesh, shape,
        compressor=args.compressor, steps=args.steps, lr=args.lr,
        ckpt=ckpt, resume=args.resume, fused=args.fused,
        clip_norm=args.clip_norm, wire=args.wire,
        overlap=args.overlap, bucket_words=args.bucket_words,
        microbatches=args.microbatches, opt=args.opt,
    )


if __name__ == "__main__":
    main()
