import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this prints/records:
  * memory_analysis()  — per-device bytes (proves the sharding fits),
  * cost_analysis()    — per-device HLO FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from compiled HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute result sizes),
  * the three roofline terms vs TPU v5e peaks.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results.jsonl
Each cell can run in its own process (the sweep driver does this) so one
compile's heap doesn't bloat the next.
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

# TPU v5e hardware constants (targets; this container is CPU-only)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from the compiled SPMD module."""
    out = {}
    for shape_s, kind in _COLL_RE.findall(hlo_text):
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_s)
    return out


def model_flops_per_chip(cfg, shape, n_chips: int) -> float:
    """6·N·D (train) / 2·N·B (decode, per emitted token), active params."""
    from repro.launch.specs import param_shapes
    import numpy as np

    g = param_shapes(cfg, 16, 1)
    n_total = int(sum(np.prod(x.shape) for x in jax.tree.leaves(g)))
    n_active = n_total
    if cfg.n_experts:  # subtract inactive expert params
        leaves = jax.tree_util.tree_flatten_with_path(g)[0]
        expert_params = sum(
            int(np.prod(l.shape))
            for p, l in leaves
            if any(getattr(q, "key", "") in ("w_gate", "w_up", "w_down") for q in p)
            and l.ndim == 4  # stacked (L, E, ...)
        )
        n_active = n_total - expert_params + expert_params * (
            (cfg.top_k + cfg.n_shared_experts) / max(cfg.n_experts, 1)
        )
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / n_chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / n_chips


def run_cell(arch: str, shape_name: str, multi_pod: bool, compressor: str = "intsgd",
             tp_override=None, remat_policy="full"):
    from repro.configs import get_arch, get_shape
    from repro.core import make_compressor
    from repro.launch.mesh import make_production_mesh
    from repro.launch.step import build_serve_step, build_train_step
    from repro.optim import sgd
    from repro.optim.schedules import constant

    import dataclasses as _dc

    cfg = get_arch(arch)
    if remat_policy != "full":
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": "full-attention arch (see DESIGN.md §shape-skips)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    if shape.kind == "train":
        art = build_train_step(
            cfg, mesh, shape,
            compressor=make_compressor(compressor),
            base_opt=sgd(momentum=0.9, weight_decay=1e-4),
            lr_schedule=constant(0.1),
            tp_override=tp_override,
        )
        fn = art.jitted["compressed"]
    else:
        art = build_serve_step(cfg, mesh, shape)
        fn = art.jitted["prefill" if shape.kind == "prefill" else "decode"]

    lowered = fn.lower(*art.arg_structs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
        ca = ca[0] if ca else {}
    hlo_flops = float(ca.get("flops", 0.0))
    hlo_bytes = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())

    # structural (jaxpr-level) cost: multiplies through scan trip counts —
    # the numbers the roofline uses (HLO cost_analysis counts while-loop
    # bodies ONCE, undercounting scanned layers by ~L×; both recorded).
    import sys as _sys, os as _os
    _sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks.jaxpr_cost import analyze, summarize

    t2 = time.time()
    struct = summarize(analyze(fn, *art.arg_structs))
    t_struct = time.time() - t2

    mf = model_flops_per_chip(cfg, shape, n_chips)
    terms = {
        "compute_s": struct["flops"] / PEAK_FLOPS,
        # post-fusion HBM estimate; struct["bytes"]/HBM_BW is the unfused
        # upper bound, also recorded
        "memory_s": struct["bytes_fused"] / HBM_BW,
        "memory_unfused_s": struct["bytes"] / HBM_BW,
        "collective_s": struct["collective_bytes"] / ICI_BW,
    }
    core_terms = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    dominant = max(core_terms, key=core_terms.get)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "n_chips": n_chips,
        "compressor": compressor if shape.kind == "train" else None,
        "tp_override": tp_override,
        "remat_policy": remat_policy,
        "struct": struct,
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "hlo_collectives": coll,
        "memory": mem,
        "roofline": terms,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_flops_frac": mf / struct["flops"] if struct["flops"] else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "struct_s": round(t_struct, 1),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compressor", default="intsgd")
    ap.add_argument("--tp", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import runnable_cells

    if args.all:
        cells = [(a, s) for a, s, r in runnable_cells() if r]
    else:
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.multi_pod, args.compressor,
                           tp_override=args.tp, remat_policy=args.remat)
        except Exception as e:  # record failures, they are bugs to fix
            rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "error": f"{type(e).__name__}: {e}"}
        line = json.dumps(rec)
        print(line, flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")


if __name__ == "__main__":
    main()
