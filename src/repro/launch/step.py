"""Train / serve step construction over the production mesh.

One ``shard_map`` per step, manual collectives inside (Megatron-JAX style,
check_vma disabled):

  * forward/backward with TP collectives (psum over "model");
  * gradients of REPLICATED params psum'd over "model" (each TP member holds
    a partial contribution);
  * IntSGD (or any baseline compressor) aggregates gradients across the
    data-parallel axes — for IntSGD the wire carries ONLY integers (psum of
    int32), the paper's contract;
  * ZeRO-1 optimizer update on dp-sharded f32 masters, bf16 param
    all-gather.

The first optimization step uses exact (float) aggregation per paper §4.1 —
drivers call the `exact` step once, then the compressed step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.comm import CommCtx
from repro.core.compressor import Compressor, aggregate_exact
from repro.core.stats import DxStats, TreeDims
from repro.launch import specs as specs_mod
from repro.launch.mesh import dp_axes_of, dp_sizes_of
from repro.models.common import Axes
from repro.models.decode import init_lm_cache, lm_decode_step, tp_greedy
from repro.models.encdec import (
    encdec_decode_step,
    encdec_loss,
    encode as encdec_encode,
    init_encdec_params,
)
from repro.models.transformer import (
    init_lm_params,
    lm_forward,
    lm_logits_local,
    lm_loss,
)
from repro.optim.base import Optimizer, apply_updates
from repro.optim.zero1 import zero1_init, zero1_state_specs, zero1_update


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _dp_spec(dp):
    return dp if len(dp) > 1 else dp[0]


def _replicated_mask(pspecs):
    return jax.tree.map(lambda s: all(p is None for p in s), pspecs)


def _fix_replicated_grads(grads, rep_mask, model_axis):
    """Replicated params receive partial grads on each TP member; sum them."""
    return jax.tree.map(
        lambda g, rep: lax.psum(g, model_axis) if rep else g, grads, rep_mask
    )


def _global_dx_stats(updates, rep_mask, model_axis) -> DxStats:
    """GLOBAL ||Δx||² from local shards with ONE psum of a stacked vector."""
    leaf_sq = jax.tree.map(
        lambda u: jnp.sum(jnp.square(u.astype(jnp.float32))), updates
    )
    leaves, treedef = jax.tree.flatten(leaf_sq)
    reps = jax.tree.leaves(rep_mask)
    vec = jnp.stack(leaves)
    if model_axis is not None:
        sharded_vec = jnp.where(jnp.asarray(reps), 0.0, vec)
        rep_vec = jnp.where(jnp.asarray(reps), vec, 0.0)
        vec = lax.psum(sharded_vec, model_axis) + rep_vec
    leaf_sq = jax.tree.unflatten(treedef, list(vec))
    return DxStats(sq=jnp.sum(vec), leaf_sq=leaf_sq)


@dataclasses.dataclass
class StepArtifacts:
    """Everything the dry-run / trainer needs for one (arch, shape, mesh)."""

    jitted: Any
    arg_structs: tuple  # ShapeDtypeStructs (global)
    in_shardings: tuple
    out_shardings: Any
    abstract_state: Any  # init-time state structs (for real runs)


def _shardings(mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _zero1_shapes_global(local_state, tp):
    def up(l):
        if l.ndim >= 2:
            return jax.ShapeDtypeStruct((l.shape[0], l.shape[1] * tp), l.dtype)
        return l

    return jax.tree.map(up, local_state)


def _comp_state_shapes(comp: Compressor, cfg, tp, n_dp):
    """Compressor state with a leading dp axis (per-worker state, e.g.
    IntDIANA shifts / EF buffers), via the global/local diff trick."""
    g_params = specs_mod.param_shapes(cfg, tp, 1)
    l_params = specs_mod.param_shapes(cfg, tp, tp)
    gs = jax.eval_shape(comp.init, g_params)
    ls = jax.eval_shape(comp.init, l_params)

    def spec(gl, lo):
        if gl.shape == lo.shape:
            base = [None] * len(gl.shape)
        else:
            diff = [i for i, (a, b) in enumerate(zip(gl.shape, lo.shape)) if a != b]
            base = [None] * len(gl.shape)
            base[diff[0]] = "model"
        return base

    pspecs = jax.tree.map(spec, gs, ls)
    glob = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_dp,) + x.shape, x.dtype), gs
    )
    return glob, pspecs


def _loss_fn_for(cfg: ModelConfig):
    return encdec_loss if cfg.family == "encdec" else lm_loss


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def build_train_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    compressor: Compressor,
    base_opt: Optimizer,
    lr_schedule: Callable,
    param_dtype=jnp.bfloat16,
    exact_first: bool = False,
    donate: bool = True,
    tp_override: Optional[int] = None,
) -> StepArtifacts:
    from repro.launch.inputs import input_specs

    tp = tp_override if tp_override is not None else mesh.shape["model"]
    if tp == 1:
        # tiny-model axis remap: the whole mesh becomes data-parallel; the
        # model is replicated and IntSGD aggregates over every chip
        dp = tuple(mesh.axis_names)
    else:
        dp = dp_axes_of(mesh)
    dp_sizes = tuple(mesh.shape[a] for a in dp)
    n_dp = 1
    for s in dp_sizes:
        n_dp *= s
    axes = Axes(tp="model", tp_size=tp) if tp > 1 else Axes()
    ctx = CommCtx(axes=dp, axis_sizes=dp_sizes, model_axis="model")
    loss_fn = _loss_fn_for(cfg)

    g_shapes, l_shapes, pspecs = specs_mod.infer_param_specs(cfg, tp)
    g_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, param_dtype), g_shapes
    )
    rep_mask = _replicated_mask(pspecs)
    dims = specs_mod.global_tree_dims(cfg, tp)

    l_params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, param_dtype), l_shapes
    )
    opt_local = jax.eval_shape(partial(zero1_init, base_opt, n_dp=n_dp), l_params)
    opt_global = _zero1_shapes_global(opt_local, tp)
    opt_specs = zero1_state_specs(
        opt_local, _dp_spec(dp), model_axis="model" if tp > 1 else None
    )
    comp_global, comp_leaf_specs = _comp_state_shapes(compressor, cfg, tp, n_dp)
    comp_specs = jax.tree.map(
        lambda x, base: P(*([_dp_spec(dp)] + list(base))),
        comp_global,
        comp_leaf_specs,
    )

    batch_struct = input_specs(cfg, shape, kind="train")
    batch_specs = specs_mod.batch_pspecs(batch_struct, dp=dp)

    def step(params, opt_state, comp_state, step_idx, key, batch, *, exact):
        eta = lr_schedule(step_idx)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, axes, cfg, dtype=jnp.bfloat16)
        )(params)
        if tp > 1:
            grads = _fix_replicated_grads(grads, rep_mask, "model")
        cs = jax.tree.map(lambda x: x[0] if x.ndim >= 1 else x, comp_state)
        if exact:
            ghat = aggregate_exact(grads, ctx)
            metrics = (jnp.zeros(()), jnp.zeros(()))
        else:
            ghat, cs, m = compressor.aggregate(
                cs, grads, key=jax.random.fold_in(key, 1), eta=eta, ctx=ctx, dims=dims
            )
            m_axes = dp + (("model",) if tp > 1 else ())
            metrics = (
                lax.pmax(m.max_int, m_axes),
                lax.pmax(m.bits_per_coord, m_axes),
            )
        dp_index = ctx.worker_index()
        new_params, new_opt = zero1_update(
            base_opt,
            opt_state,
            ghat,
            eta,
            dp_axes=dp,
            dp_index=dp_index,
            n_dp=n_dp,
            param_dtype=param_dtype,
            params_like=params,
        )
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params,
            params,
        )
        dx_stats = _global_dx_stats(delta, rep_mask, "model" if tp > 1 else None)
        cs = compressor.observe_update(cs, dx_stats)
        new_comp = jax.tree.map(lambda x: x[None] if x.ndim >= 0 else x, cs)
        new_comp = jax.tree.map(
            lambda x, like: x.reshape(like.shape), new_comp, comp_state
        )
        loss_g = lax.psum(loss, dp) / n_dp
        return new_params, new_opt, new_comp, loss_g, metrics

    in_specs = (
        pspecs,
        opt_specs,
        comp_specs,
        P(),
        P(),
        batch_specs,
    )
    out_specs = (pspecs, opt_specs, comp_specs, P(), (P(), P()))

    def make(exact):
        sm = jax.shard_map(
            partial(step, exact=exact),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
        return jax.jit(
            sm,
            in_shardings=_shardings(mesh, in_specs),
            out_shardings=_shardings(mesh, out_specs),
            donate_argnums=(0, 1, 2) if donate else (),
        )

    arg_structs = (
        g_shapes,
        opt_global,
        comp_global,
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
        batch_struct,
    )
    return StepArtifacts(
        jitted={"compressed": make(False), "exact": make(True)},
        arg_structs=arg_structs,
        in_shardings=_shardings(mesh, in_specs),
        out_shardings=_shardings(mesh, out_specs),
        abstract_state=None,
    )


def build_init_state(
    cfg: ModelConfig,
    mesh,
    *,
    compressor: Compressor,
    base_opt: Optimizer,
):
    """jitted (global params) -> (opt_state, comp_state) with correct
    ZeRO-1 layout (masters == initial params) and dp-stacked compressor
    state."""
    dp = dp_axes_of(mesh)
    dp_sizes = dp_sizes_of(mesh)
    n_dp = 1
    for s in dp_sizes:
        n_dp *= s
    tp = mesh.shape["model"]
    ctx = CommCtx(axes=dp, axis_sizes=dp_sizes, model_axis="model")
    _, l_shapes, pspecs = specs_mod.infer_param_specs(cfg, tp)
    l_params_f32 = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), l_shapes
    )
    opt_local = jax.eval_shape(
        partial(zero1_init, base_opt, n_dp=n_dp), l_params_f32
    )
    opt_specs = zero1_state_specs(
        opt_local, _dp_spec(dp), model_axis="model" if tp > 1 else None
    )
    comp_global, comp_leaf_specs = _comp_state_shapes(compressor, cfg, tp, n_dp)
    comp_specs = jax.tree.map(
        lambda x, base: P(*([_dp_spec(dp)] + list(base))),
        comp_global,
        comp_leaf_specs,
    )

    from repro.optim.zero1 import shard_leaf

    def init_fn(params):
        dp_index = ctx.worker_index()
        masters_full = jax.tree.map(lambda p: shard_leaf(p, n_dp), params)
        my = jax.tree.map(
            lambda m: lax.dynamic_slice_in_dim(m, dp_index, 1, 0), masters_full
        )
        base_state = base_opt.init(jax.tree.map(lambda m: m[0], my))
        restack = lambda t: jax.tree.map(
            lambda x: x[None] if x.ndim >= 1 else x, t
        )
        opt_state = {"master": my, "base": restack(base_state)}
        cs = compressor.init(params)
        cs = jax.tree.map(lambda x: jnp.asarray(x)[None], cs)
        return opt_state, cs

    sm = jax.shard_map(
        init_fn,
        mesh=mesh,
        in_specs=(pspecs,),
        out_specs=(opt_specs, comp_specs),
        check_vma=False,
    )
    return jax.jit(
        sm,
        in_shardings=(_shardings(mesh, pspecs),),
        out_shardings=_shardings(mesh, (opt_specs, comp_specs)),
    )


# ---------------------------------------------------------------------------
# serve steps (prefill / decode)
# ---------------------------------------------------------------------------
def build_serve_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    param_dtype=jnp.bfloat16,
) -> StepArtifacts:
    from repro.launch.inputs import input_specs

    dp = dp_axes_of(mesh)
    dp_sizes = dp_sizes_of(mesh)
    n_dp = 1
    for s in dp_sizes:
        n_dp *= s
    tp = mesh.shape["model"]
    seq_sharded = shape.kind == "decode" and shape.global_batch < n_dp
    if seq_sharded:
        axes = Axes(tp="model", tp_size=tp, sp=dp, sp_sizes=dp_sizes)
        b_local = shape.global_batch
        s_local = shape.seq_len // n_dp
    else:
        axes = Axes(tp="model", tp_size=tp)
        b_local = max(1, shape.global_batch // n_dp)
        s_local = shape.seq_len

    g_shapes, l_shapes, pspecs = specs_mod.infer_param_specs(cfg, tp)
    g_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, param_dtype), g_shapes
    )

    if shape.kind == "prefill":
        batch_struct = input_specs(cfg, shape, kind="prefill")
        batch_specs = specs_mod.batch_pspecs(batch_struct, dp=dp)

        def prefill(params, batch):
            if cfg.family == "encdec":
                h = encdec_encode(params, batch["frames"], axes, cfg)
                logits = jnp.einsum(
                    "btd,dv->btv", h[:, -1:], params["lm_head"].astype(h.dtype)
                ).astype(jnp.float32)[:, 0]
            else:
                h = lm_forward(params, batch, axes, cfg)
                logits = lm_logits_local(params, h[:, -1:], cfg)[:, 0]
            return logits

        in_specs = (pspecs, batch_specs)
        out_specs = P(_dp_spec(dp), "model")
        sm = jax.shard_map(
            prefill, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        jitted = jax.jit(sm, in_shardings=_shardings(mesh, in_specs))
        arg_structs = (g_shapes, batch_struct)
        return StepArtifacts(
            jitted={"prefill": jitted},
            arg_structs=arg_structs,
            in_shardings=_shardings(mesh, in_specs),
            out_shardings=None,
            abstract_state=None,
        )

    # decode
    cache_local = specs_mod.cache_shapes(
        cfg, tp, tp, b_local, s_local, s_src=min(shape.seq_len, 32768)
    )
    cache_specs = specs_mod.cache_pspecs(
        cache_local, dp=dp, seq_sharded=seq_sharded
    )

    def to_global(struct, spec):
        shape_l = list(struct.shape)
        for i, p in enumerate(spec):
            if p is None:
                continue
            size = tp if p == "model" else n_dp
            shape_l[i] = shape_l[i] * size
        return jax.ShapeDtypeStruct(tuple(shape_l), struct.dtype)

    cache_global = jax.tree.map(
        to_global, cache_local, cache_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    tok_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_spec = P() if seq_sharded else P(_dp_spec(dp))

    def decode(params, cache, tokens, pos):
        if cfg.family == "encdec":
            logits, new_cache = encdec_decode_step(
                params, cache, tokens, pos, axes, cfg
            )
        else:
            logits, new_cache = lm_decode_step(params, cache, tokens, pos, axes, cfg)
        next_tok = tp_greedy(logits, axes)
        return next_tok, new_cache

    in_specs = (pspecs, cache_specs, tok_spec, tok_spec)
    out_specs = (tok_spec, cache_specs)
    sm = jax.shard_map(
        decode, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    jitted = jax.jit(
        sm,
        in_shardings=_shardings(mesh, in_specs),
        out_shardings=_shardings(mesh, out_specs),
        donate_argnums=(1,),
    )
    arg_structs = (g_shapes, cache_global, tok_struct, pos_struct)
    return StepArtifacts(
        jitted={"decode": jitted},
        arg_structs=arg_structs,
        in_shardings=_shardings(mesh, in_specs),
        out_shardings=_shardings(mesh, out_specs),
        abstract_state=None,
    )
