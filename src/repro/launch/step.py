"""Train / serve / eval step construction over the production mesh.

One ``shard_map`` per step (via the version-portable layer
:mod:`repro.parallel.collectives`), manual collectives inside (Megatron-JAX
style, replication checks disabled):

  * forward/backward with TP collectives (psum over "model");
  * gradients of REPLICATED params psum'd over "model" (each TP member holds
    a partial contribution);
  * IntSGD (or any baseline compressor) aggregates gradients across the
    data-parallel axes — for the integer-wire families the psum carries ONLY
    the wire codec's transport words (narrow lanes or bit-packed int32
    words, selected via the compressor's ``wire`` field or the ``wire=``
    argument here — see repro.wire), the paper's no-floats contract;
  * optimizer update, routed one of two ways:
      - "zero1": ZeRO-1 update on dp-sharded f32 masters, bf16 param
        all-gather (the default);
      - "fused": the Pallas decode+update kernel family — integer
        dequantization folded into the optimizer step (momentum-SGD or
        bias-corrected AdamW, plus the IntDIANA global-shift add/advance),
        one HBM pass, params updated in place of a master copy; consumes
        the codec's transport words directly (packed words are unpacked
        in-register, never in HBM). Routed by capability
        (Compressor.fused_capable × Optimizer.fused_kernel), never by
        concrete type — see _fused_plan.

  * wire transport is either one monolithic psum (``overlap="off"``, the
    serial reference) or bucketed ``lax.ppermute`` rings
    (``overlap="ring"``) that XLA's scheduler hides behind pending compute;
    with ``microbatches > 1`` the train body encodes and LAUNCHES each
    microbatch's integer image as soon as its backward finishes, so bucket
    k of microbatch i reduces while backward of microbatch i+1 runs. Both
    routes decode bit-identically (integer sums are exact in any order).

Every builder (train / init / serve / eval) resolves the SAME
:class:`Layout` and terminates in the SAME ``collectives.sharded_jit``
pipeline — there is exactly one shard_map+jit construction path.

The first optimization step uses exact (float) aggregation per paper §4.1 —
drivers call the `exact` step once, then the compressed step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.comm import CommCtx
from repro.core.compressor import (
    Compressor,
    aggregate_exact,
    with_wire,
)
from repro.core.stats import DxStats, TreeDims, scale_dx_stats
from repro.launch import specs as specs_mod
from repro.models.common import Axes
from repro.models.decode import lm_decode_step, tp_greedy
from repro.models.encdec import (
    encdec_decode_step,
    encdec_loss,
    encode as encdec_encode,
)
from repro.models.transformer import lm_forward, lm_logits_local, lm_loss
from repro.optim import base as optb
from repro.optim.base import Optimizer
from repro.optim.zero1 import zero1_init, zero1_state_specs, zero1_update
from repro.parallel import collectives as coll
from repro.utils.tree import tree_abs_max
from repro.wire import bucketing


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _replicated_mask(pspecs):
    return jax.tree.map(lambda s: all(p is None for p in s), pspecs)


def _fix_replicated_grads(grads, rep_mask, model_axis):
    """Replicated params receive partial grads on each TP member; sum them."""
    return jax.tree.map(
        lambda g, rep: coll.psum(g, model_axis) if rep else g, grads, rep_mask
    )


def _global_reduce_leaf_sq(leaf_sq, rep_mask, model_axis) -> DxStats:
    """Reduce local per-leaf squared norms to GLOBAL values with ONE psum of
    a stacked vector (TP-sharded leaves summed over "model", replicated
    leaves passed through)."""
    leaves, treedef = jax.tree.flatten(leaf_sq)
    reps = jax.tree.leaves(rep_mask)
    vec = jnp.stack(leaves)
    if model_axis is not None:
        sharded_vec = jnp.where(jnp.asarray(reps), 0.0, vec)
        rep_vec = jnp.where(jnp.asarray(reps), vec, 0.0)
        vec = coll.psum(sharded_vec, model_axis) + rep_vec
    leaf_sq = jax.tree.unflatten(treedef, list(vec))
    return DxStats(sq=jnp.sum(vec), leaf_sq=leaf_sq)


def _global_dx_stats(updates, rep_mask, model_axis) -> DxStats:
    """GLOBAL ||Δx||² from local shards."""
    leaf_sq = jax.tree.map(
        lambda u: jnp.sum(jnp.square(u.astype(jnp.float32))), updates
    )
    return _global_reduce_leaf_sq(leaf_sq, rep_mask, model_axis)


@dataclasses.dataclass
class StepArtifacts:
    """Everything the dry-run / trainer needs for one (arch, shape, mesh)."""

    jitted: Any
    arg_structs: tuple  # ShapeDtypeStructs (global)
    in_shardings: tuple
    out_shardings: Any
    abstract_state: Any  # init-time state structs (for real runs)
    audit_spec: Any = None  # wire_audit.WireSpec declaring the step's
    # (dp axes, codec, n_workers, n_accum) contract — what the static
    # auditor proves the traced jaxpr against


def _zero1_shapes_global(local_state, tp):
    def up(l):
        if l.ndim >= 2:
            return jax.ShapeDtypeStruct((l.shape[0], l.shape[1] * tp), l.dtype)
        return l

    return jax.tree.map(up, local_state)


def _comp_state_shapes(comp: Compressor, cfg, tp, n_dp):
    """Compressor state with a leading dp axis (per-worker state, e.g.
    IntDIANA shifts / EF buffers), via the global/local diff trick."""
    g_params = specs_mod.param_shapes(cfg, tp, 1)
    l_params = specs_mod.param_shapes(cfg, tp, tp)
    gs = jax.eval_shape(comp.init, g_params)
    ls = jax.eval_shape(comp.init, l_params)

    def spec(gl, lo):
        if gl.shape == lo.shape:
            base = [None] * len(gl.shape)
        else:
            diff = [i for i, (a, b) in enumerate(zip(gl.shape, lo.shape)) if a != b]
            base = [None] * len(gl.shape)
            base[diff[0]] = "model"
        return base

    pspecs = jax.tree.map(spec, gs, ls)
    glob = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_dp,) + x.shape, x.dtype), gs
    )
    return glob, pspecs


def _loss_fn_for(cfg: ModelConfig):
    return encdec_loss if cfg.family == "encdec" else lm_loss


def _fused_state_struct(base_opt: Optimizer, shapes):
    """ShapeDtypeStructs of the fused-route optimizer state for ``shapes``
    (f32 tensor per param per FUSED_STATE_TENSORS entry + int32 scalars)."""
    kern = base_opt.fused_kernel
    st = {
        nm: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), shapes
        )
        for nm in optb.FUSED_STATE_TENSORS[kern]
    }
    for nm in optb.FUSED_STATE_SCALARS[kern]:
        st[nm] = jax.ShapeDtypeStruct((), jnp.int32)
    return st


def _fused_state_specs(base_opt: Optimizer, pspecs):
    kern = base_opt.fused_kernel
    specs = {nm: pspecs for nm in optb.FUSED_STATE_TENSORS[kern]}
    for nm in optb.FUSED_STATE_SCALARS[kern]:
        specs[nm] = P()
    return specs


# ---------------------------------------------------------------------------
# layout resolution — ONE place derives (tp, dp, specs) for every builder
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Layout:
    """Resolved execution layout of (cfg, mesh): axes, specs and masks the
    train / init / serve / eval builders all share."""

    cfg: ModelConfig
    mesh: Any
    tp: int
    dp: tuple  # data-parallel (gradient-sync) axis names
    dp_sizes: tuple
    n_dp: int
    axes: Axes  # model-code axis handles (TP)
    ctx: CommCtx  # compressor communication context
    pspecs: Any  # param PartitionSpecs
    rep_mask: Any  # which param leaves are TP-replicated
    g_shapes: Any  # global param ShapeDtypeStructs (param_dtype)
    l_shapes: Any  # local param ShapeDtypeStructs (param_dtype)
    dims: TreeDims  # global model dimensionality (α's d)

    @property
    def dp_spec(self):
        return coll.axis_spec(self.dp)

    @property
    def model_axis(self) -> Optional[str]:
        return "model" if self.tp > 1 else None


def resolve_layout(
    cfg: ModelConfig,
    mesh,
    *,
    param_dtype=jnp.bfloat16,
    tp_override: Optional[int] = None,
    remap_tp1: bool = False,
    overlap: str = "off",
    bucket_words: int = bucketing.DEFAULT_BUCKET_WORDS,
) -> Layout:
    """Derive the layout. With ``remap_tp1`` (train path), a tp==1 override
    turns the whole mesh data-parallel: the model is replicated and IntSGD
    aggregates over every chip. ``overlap``/``bucket_words`` configure the
    wire transport on the resulting CommCtx ("off" = one monolithic psum,
    "ring" = bucketed ppermute rings XLA can hide behind compute)."""
    tp = tp_override if tp_override is not None else mesh.shape["model"]
    if remap_tp1 and tp == 1:
        dp = tuple(mesh.axis_names)
    else:
        dp = coll.dp_axes_of(mesh)
    dp_sizes = tuple(mesh.shape[a] for a in dp)
    n_dp = 1
    for s in dp_sizes:
        n_dp *= s
    axes = Axes(tp="model", tp_size=tp) if tp > 1 else Axes()
    ctx = CommCtx(
        axes=dp, axis_sizes=dp_sizes, model_axis="model",
        overlap=overlap, bucket_words=bucket_words,
    )
    g_shapes, l_shapes, pspecs = specs_mod.infer_param_specs(cfg, tp)
    cast = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, param_dtype), t
    )
    return Layout(
        cfg=cfg,
        mesh=mesh,
        tp=tp,
        dp=dp,
        dp_sizes=dp_sizes,
        n_dp=n_dp,
        axes=axes,
        ctx=ctx,
        pspecs=pspecs,
        rep_mask=_replicated_mask(pspecs),
        g_shapes=cast(g_shapes),
        l_shapes=cast(l_shapes),
        dims=specs_mod.global_tree_dims(cfg, tp),
    )


def _sharded(layout: Layout, body, in_specs, out_specs, *, donate=(),
             shard_outputs=True):
    """The single shard_map+jit pipeline every builder terminates in."""
    return coll.sharded_jit(
        body,
        layout.mesh,
        in_specs,
        out_specs,
        donate=donate,
        shard_outputs=shard_outputs,
    )


# ---------------------------------------------------------------------------
# shared step-body stages
# ---------------------------------------------------------------------------
def _forward_backward(layout: Layout, loss_fn, params, batch):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, layout.axes, layout.cfg, dtype=jnp.bfloat16)
    )(params)
    if layout.tp > 1:
        grads = _fix_replicated_grads(grads, layout.rep_mask, "model")
    return loss, grads


def _unstack_comp(comp_state):
    return jax.tree.map(lambda x: x[0] if x.ndim >= 1 else x, comp_state)


def _restack_comp(cs, comp_state_like):
    new = jax.tree.map(lambda x: x[None] if x.ndim >= 0 else x, cs)
    return jax.tree.map(
        lambda x, like: x.reshape(like.shape), new, comp_state_like
    )


def _observe_dx(layout: Layout, compressor, base_opt, cs, new_params, params):
    """Δx stats -> α rule, rescaled to gradient-equivalent units
    (base_opt.dx_scale — §4.1 momentum correction)."""
    delta = jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        new_params,
        params,
    )
    dx_stats = _global_dx_stats(delta, layout.rep_mask, layout.model_axis)
    return compressor.observe_update(
        cs, scale_dx_stats(dx_stats, base_opt.dx_scale)
    )


def _fused_plan(base_opt: Optimizer, compressor: Compressor) -> str:
    """Validate the (compressor × optimizer) pair against the fused-route
    capability contract and return the kernel name. No type-gates: the
    compressor advertises wire-level aggregation via ``fused_capable``, the
    optimizer its Pallas decode+update kernel via ``Optimizer.fused_kernel``
    — any capable pair routes, any other names the missing capability."""
    if not getattr(compressor, "fused_capable", False):
        wf = getattr(compressor, "wire_format", None)
        if wf is not None and not getattr(wf, "fused_capable", True):
            raise ValueError(
                "fused update routing consumes the summed transport words "
                f"directly, but wire codec {wf.name!r} has no fused "
                "decode+update kernel (WireFormat.fused_capable): its "
                f"gather-transport payload (planes "
                f"{getattr(wf, 'plane_names', ())!r}) needs a scatter-shaped "
                "decode — use a psum-transport codec (dense/packed) or "
                "fused=False"
            )
        raise ValueError(
            "fused update routing consumes the summed transport words "
            "directly, which needs wire-level aggregation "
            f"(Compressor.fused_capable); compressor {compressor.name!r} "
            "does not advertise it — use an integer-wire compressor or "
            "fused=False"
        )
    if base_opt.fused_kernel is None or base_opt.hyper is None:
        raise ValueError(
            "fused update routing needs an optimizer exposing a fused "
            "decode+update kernel (Optimizer.fused_kernel); "
            f"kind={base_opt.kind!r} advertises none — use optim.sgd "
            "(heavy-ball) or optim.adamw, or fused=False"
        )
    return base_opt.fused_kernel


def _clip_factor(layout: Layout, clip_norm, *, ghat=None, int_sum=None,
                 alphas=None, shift=None):
    """Global-norm gradient clip factor min(1, c/||ĝ||). For the fused
    integer route ||ĝ||² is computed straight off the wire payload
    (||ĝ_l||² = ||Σints_l||²/(nα_l)², plus the replicated shift h for the
    IntDIANA decode ĝ = h + Σints/(nα)) so ĝ is never materialized — the
    elementwise add fuses into the reduction."""
    if int_sum is not None:
        n = layout.ctx.n
        if shift is None:
            leaf_sq = jax.tree.map(
                lambda s, a: jnp.sum(jnp.square(s.astype(jnp.float32)))
                / jnp.square(n * a),
                int_sum,
                alphas,
            )
        else:
            leaf_sq = jax.tree.map(
                lambda s, a, h: jnp.sum(
                    jnp.square(h + s.astype(jnp.float32) / (n * a))
                ),
                int_sum,
                alphas,
                shift,
            )
    else:
        leaf_sq = jax.tree.map(
            lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), ghat
        )
    sq = _global_reduce_leaf_sq(leaf_sq, layout.rep_mask, layout.model_axis).sq
    return jnp.minimum(1.0, clip_norm / (jnp.sqrt(sq) + 1e-12))


def _microbatch(batch, m: int, n_micro: int):
    """Static slice m of n_micro along the (local) batch dim of every leaf."""
    def one(v):
        b = v.shape[0] // n_micro
        return v[m * b : (m + 1) * b]

    return jax.tree.map(one, batch)


def _pipelined_grad_stage(
    layout: Layout, loss_fn, compressor: Compressor, cs, params, batch, akey,
    eta, n_micro: int,
):
    """Microbatch/grad-accum wire pipelining: encode microbatch i's integer
    image and LAUNCH its (bucketed) all-reduce immediately, then start
    backward of microbatch i+1 — the data dependencies leave bucket k of
    image i free to ride the wire while compute i+1 runs, which is exactly
    the overlap XLA's latency-hiding scheduler exploits on the ring route.

    Math: each microbatch image is clipped for the FULL n·M accumulated sum
    (``encode_ints(n_accum=M)`` — so the int32 accumulator can never wrap,
    even on a 32-bit wire with clip-saturating gradients) and reduced
    separately; the M summed images then add exactly, so

        ghat = (1/(n·M·α)) Σ_m Σ_i Int(α g_i^m)

    is the mean of M independent estimates (for IntDIANA each image carries
    the difference g^m - h_i/M, so the mean estimates g - h_i) — the same
    estimator whether the transport is the serial psum or the bucketed
    rings (parity is pinned by tests/test_overlap.py). Decode + compressor
    state advance happen in ``compressor.finish_pipelined``; compressors
    whose state reads the LOCAL integer image (``fused_local_state``, e.g.
    IntDIANA's h_local) get the local accumulation too."""
    track_local = compressor.fused_local_state
    wf = compressor.wire_format
    loss_acc = jnp.zeros(())
    max_int = jnp.zeros(())
    int_acc = local_acc = alphas = None
    for m in range(n_micro):
        mb = _microbatch(batch, m, n_micro)
        loss_m, grads_m = _forward_backward(layout, loss_fn, params, mb)
        ints_m, alphas = compressor.encode_ints(
            cs, grads_m, key=jax.random.fold_in(akey, m), eta=eta,
            ctx=layout.ctx, dims=layout.dims, n_accum=n_micro,
        )
        if track_local:
            local_acc = (
                ints_m if local_acc is None
                else jax.tree.map(jnp.add, local_acc, ints_m)
            )
        # the reduce of image m is issued HERE, before backward of m+1 —
        # no result of it is needed until the decode after the loop
        _, int_sum_m = layout.ctx.psum_wire(ints_m, wf)
        int_acc = (
            int_sum_m if int_acc is None
            else jax.tree.map(jnp.add, int_acc, int_sum_m)
        )
        # wire-width metric: what each psum actually carried, not the
        # M-fold accumulated sum
        max_int = jnp.maximum(max_int, tree_abs_max(int_sum_m))
        loss_acc = loss_acc + loss_m
    ghat, cs = compressor.finish_pipelined(
        cs, int_acc, local_acc, alphas, ctx=layout.ctx, n_accum=n_micro
    )
    bits = 1.0 + jnp.ceil(jnp.log2(jnp.maximum(max_int, 1.0) + 1.0))
    return ghat, cs, loss_acc / n_micro, (max_int, bits)


def _accum_grad_stage(layout: Layout, loss_fn, params, batch, n_micro: int):
    """Plain gradient accumulation (exact step / non-IntSGD compressors):
    mean of the microbatch gradients in f32, one aggregation afterwards."""
    loss_acc = jnp.zeros(())
    g_acc = None
    for m in range(n_micro):
        mb = _microbatch(batch, m, n_micro)
        loss_m, grads_m = _forward_backward(layout, loss_fn, params, mb)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads_m)
        g_acc = g32 if g_acc is None else jax.tree.map(jnp.add, g_acc, g32)
        loss_acc = loss_acc + loss_m
    grads = jax.tree.map(lambda g: g / n_micro, g_acc)
    return loss_acc / n_micro, grads


def _make_train_body(
    layout: Layout,
    *,
    loss_fn,
    compressor: Compressor,
    base_opt: Optimizer,
    lr_schedule: Callable,
    param_dtype,
    exact: bool,
    update_route: str,  # "zero1" | "fused"
    clip_norm: Optional[float] = None,
    microbatches: int = 1,
):
    """The ONE train/optimize step body, parameterized by (loss, compressor,
    optimizer, fused-kernel routing, clipping, microbatch pipelining). All
    jitted train variants are built from it."""
    if update_route == "fused":
        _fused_plan(base_opt, compressor)
    # the microbatch wire pipelining rides the SAME capability as the fused
    # route: compressors advertising wire-level aggregation (encode_ints /
    # finish_pipelined) pipeline their integer images; everything else gets
    # plain f32 gradient accumulation
    pipelined = microbatches > 1 and compressor.fused_capable

    def step(params, opt_state, comp_state, step_idx, key, batch):
        eta = lr_schedule(step_idx)
        cs = _unstack_comp(comp_state)
        wa = alphas = None
        akey = jax.random.fold_in(key, 1)
        m_axes = layout.dp + (("model",) if layout.tp > 1 else ())
        if not exact and pipelined:
            ghat, cs, loss, (max_int, bits) = _pipelined_grad_stage(
                layout, loss_fn, compressor, cs, params, batch, akey, eta,
                microbatches,
            )
            metrics = (coll.pmax(max_int, m_axes), coll.pmax(bits, m_axes))
        else:
            if microbatches > 1:
                loss, grads = _accum_grad_stage(
                    layout, loss_fn, params, batch, microbatches
                )
            else:
                loss, grads = _forward_backward(layout, loss_fn, params, batch)
            if exact:
                ghat = aggregate_exact(grads, layout.ctx)
                metrics = (jnp.zeros(()), jnp.zeros(()))
            else:
                if update_route == "fused":
                    wa, alphas, cs, m = compressor.aggregate_wire(
                        cs, grads, key=akey, eta=eta, ctx=layout.ctx,
                        dims=layout.dims,
                    )
                    ghat = None
                else:
                    ghat, cs, m = compressor.aggregate(
                        cs, grads, key=akey, eta=eta, ctx=layout.ctx,
                        dims=layout.dims,
                    )
                metrics = (
                    coll.pmax(m.max_int, m_axes),
                    coll.pmax(m.bits_per_coord, m_axes),
                )

        # replicated global shift the fused decode must add (IntDIANA's
        # h_global; None for shift-free compressors)
        shift = compressor.fused_shift(cs) if wa is not None else None
        clip_scale = jnp.float32(1.0)
        if clip_norm is not None:
            scale = _clip_factor(
                layout, clip_norm, ghat=ghat,
                int_sum=None if wa is None else wa.ints, alphas=alphas,
                shift=shift,
            )
            if ghat is not None:
                ghat = jax.tree.map(lambda g: g * scale, ghat)
            else:  # fused: the clip rides the kernels' scalar vector
                clip_scale = scale

        if update_route == "fused":
            new_params, new_opt, new_shift = _fused_update_stage(
                layout, params, opt_state, eta, base_opt,
                ghat=ghat, wire_agg=wa, alphas=alphas,
                wf=compressor.wire_format, clip_scale=clip_scale,
                shift=shift,
            )
            if new_shift is not None:
                cs = compressor.fused_store_shift(cs, new_shift)
        else:
            new_params, new_opt = zero1_update(
                base_opt,
                opt_state,
                ghat,
                eta,
                dp_axes=layout.dp,
                dp_index=layout.ctx.worker_index(),
                n_dp=layout.n_dp,
                param_dtype=param_dtype,
                params_like=params,
            )
        cs = _observe_dx(layout, compressor, base_opt, cs, new_params, params)
        new_comp = _restack_comp(cs, comp_state)
        loss_g = coll.psum(loss, layout.dp) / layout.n_dp
        return new_params, new_opt, new_comp, loss_g, metrics

    return step


def _fused_update_stage(layout: Layout, params, opt_state, eta,
                        base_opt: Optimizer, *, ghat, wire_agg, alphas, wf,
                        clip_scale, shift):
    """Pallas fused dequantize+optimizer route: one HBM pass per leaf,
    params updated directly (no ZeRO master shard). The update consumes the
    summed TRANSPORT WORDS exactly as they left the all-reduce — for the
    packed codec the integer image is never materialized; the kernel unpacks
    fields in-register (wf.fused_update dispatch on
    ``base_opt.fused_kernel``). With a shift tree (IntDIANA) the kernel also
    emits the advanced global shift in the same pass. The exact (step-0)
    path has no integer payload and runs the same arithmetic unfused
    (optim.base.fused_reference_update).

    Returns ``(new_params, new_opt_state, new_shift | None)``."""
    if wire_agg is None:  # exact aggregation path
        new_params, new_opt = optb.fused_reference_update(
            base_opt, ghat, params, opt_state, eta
        )
        return new_params, new_opt, None

    kern = base_opt.fused_kernel
    tail, new_scalars = optb.fused_step_scalars(base_opt, opt_state, eta)
    tensor_names = optb.FUSED_STATE_TENSORS[kern]
    n = layout.ctx.n

    p_leaves, treedef = jax.tree.flatten(params)
    w_leaves = treedef.flatten_up_to(wire_agg.words)
    a_leaves = treedef.flatten_up_to(alphas)
    s_leaves = (
        treedef.flatten_up_to(shift) if shift is not None
        else [None] * len(p_leaves)
    )
    state_leaves = [treedef.flatten_up_to(opt_state[nm]) for nm in tensor_names]

    new_p, new_h = [], []
    new_state = [[] for _ in tensor_names]
    for i, (p, w, a, h) in enumerate(zip(p_leaves, w_leaves, a_leaves, s_leaves)):
        scalars = jnp.stack([1.0 / (n * a), clip_scale, *tail])
        po, oo, ho = wf.fused_update(
            w, p, tuple(sl[i] for sl in state_leaves), scalars,
            kernel=kern, n_summed=n, shift=h,
        )
        new_p.append(po)
        new_h.append(ho)
        for acc, o in zip(new_state, oo):
            acc.append(o)

    unflat = lambda leaves: jax.tree.unflatten(treedef, leaves)
    new_opt = {nm: unflat(ls) for nm, ls in zip(tensor_names, new_state)}
    new_opt.update(new_scalars)
    return (
        unflat(new_p),
        new_opt,
        unflat(new_h) if shift is not None else None,
    )


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def build_train_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    compressor: Compressor,
    base_opt: Optimizer,
    lr_schedule: Callable,
    param_dtype=jnp.bfloat16,
    exact_first: bool = False,
    donate: bool = True,
    tp_override: Optional[int] = None,
    fused: bool = False,
    clip_norm: Optional[float] = None,
    wire=None,
    overlap: str = "off",
    bucket_words: int = bucketing.DEFAULT_BUCKET_WORDS,
    microbatches: int = 1,
    verify: Optional[str] = None,
) -> StepArtifacts:
    from repro.launch.inputs import input_specs

    if verify not in (None, "static"):
        raise ValueError(f"verify must be None or 'static', got {verify!r}")

    if wire is not None:
        # config-level codec selection: rebind the compressor's transport
        # (accepts a repro.wire registry name or a WireFormat instance)
        compressor = with_wire(compressor, wire)
    if microbatches > 1 and fused:
        raise ValueError(
            "microbatch pipelining accumulates summed integer images, which "
            "the fused packed-word kernel cannot consume; use the zero1 "
            "route (fused=False) with microbatches > 1"
        )
    if microbatches < 1:
        raise ValueError(f"microbatches must be >= 1, got {microbatches}")
    layout = resolve_layout(
        cfg, mesh, param_dtype=param_dtype, tp_override=tp_override,
        remap_tp1=True, overlap=overlap, bucket_words=bucket_words,
    )
    if microbatches > 1:
        local_batch = shape.global_batch // layout.n_dp
        if local_batch % microbatches:
            raise ValueError(
                f"local batch {local_batch} (global {shape.global_batch} over "
                f"{layout.n_dp} workers) is not divisible into "
                f"{microbatches} microbatches"
            )
    loss_fn = _loss_fn_for(cfg)

    if fused:
        _fused_plan(base_opt, compressor)  # fail at build time, not trace
        opt_local = _fused_state_struct(base_opt, layout.l_shapes)
        opt_global = _fused_state_struct(base_opt, layout.g_shapes)
        opt_specs = _fused_state_specs(base_opt, layout.pspecs)
    else:
        opt_local = jax.eval_shape(
            partial(zero1_init, base_opt, n_dp=layout.n_dp), layout.l_shapes
        )
        opt_global = _zero1_shapes_global(opt_local, layout.tp)
        opt_specs = zero1_state_specs(
            opt_local, layout.dp_spec, model_axis=layout.model_axis
        )
    comp_global, comp_leaf_specs = _comp_state_shapes(
        compressor, cfg, layout.tp, layout.n_dp
    )
    comp_specs = jax.tree.map(
        lambda x, base: P(*([layout.dp_spec] + list(base))),
        comp_global,
        comp_leaf_specs,
    )

    batch_struct = input_specs(cfg, shape, kind="train")
    batch_specs = specs_mod.batch_pspecs(batch_struct, dp=layout.dp)

    in_specs = (
        layout.pspecs,
        opt_specs,
        comp_specs,
        P(),
        P(),
        batch_specs,
    )
    out_specs = (layout.pspecs, opt_specs, comp_specs, P(), (P(), P()))

    def make(exact):
        body = _make_train_body(
            layout,
            loss_fn=loss_fn,
            compressor=compressor,
            base_opt=base_opt,
            lr_schedule=lr_schedule,
            param_dtype=param_dtype,
            exact=exact,
            update_route="fused" if fused else "zero1",
            clip_norm=clip_norm,
            microbatches=microbatches,
        )
        return _sharded(
            layout, body, in_specs, out_specs,
            donate=(0, 1, 2) if donate else (),
        )

    arg_structs = (
        layout.g_shapes,
        opt_global,
        comp_global,
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
        batch_struct,
    )
    # declare the wire contract the static auditor proves the trace against
    # (float-wire baselines like NoCompression have no codec and no spec).
    # n_accum is the number of IMAGES that ride the wire per step: M for the
    # pipelined body, but 1 when the compressor cannot pipeline (not
    # fused_capable — e.g. a gather-transport codec), because that body
    # accumulates float grads and aggregates once.
    wf = getattr(compressor, "wire_format", None)
    if wf is not None:
        from repro.analysis.wire_audit import spec_for_step

        n_images = microbatches if compressor.fused_capable else 1
        audit_spec = spec_for_step(
            layout, wf, n_accum=n_images, fused=fused
        )
    else:
        audit_spec = None
    artifacts = StepArtifacts(
        jitted={"compressed": make(False), "exact": make(True)},
        arg_structs=arg_structs,
        in_shardings=coll.named_shardings(mesh, in_specs),
        out_shardings=coll.named_shardings(mesh, out_specs),
        abstract_state=None,
        audit_spec=audit_spec,
    )
    if verify == "static":
        if audit_spec is None:
            raise ValueError(
                "verify='static' needs an integer wire to prove; "
                f"compressor {type(compressor).__name__} has no wire_format"
            )
        from repro.analysis.schedule import verify_step

        verify_step(artifacts).raise_if_failed()
    return artifacts


def build_init_state(
    cfg: ModelConfig,
    mesh,
    *,
    compressor: Compressor,
    base_opt: Optimizer,
    fused: bool = False,
):
    """jitted (global params) -> (opt_state, comp_state) with correct
    optimizer layout — ZeRO-1 masters (== initial params) by default, a
    replicated f32 momentum tree for the fused route — and dp-stacked
    compressor state."""
    layout = resolve_layout(cfg, mesh, param_dtype=jnp.float32)
    comp_global, comp_leaf_specs = _comp_state_shapes(
        compressor, cfg, layout.tp, layout.n_dp
    )
    comp_specs = jax.tree.map(
        lambda x, base: P(*([layout.dp_spec] + list(base))),
        comp_global,
        comp_leaf_specs,
    )

    if fused:
        _fused_plan(base_opt, compressor)
        opt_specs = _fused_state_specs(base_opt, layout.pspecs)

        def init_fn(params):
            opt_state = optb.fused_state_init(base_opt, params)
            cs = compressor.init(params)
            cs = jax.tree.map(lambda x: jnp.asarray(x)[None], cs)
            return opt_state, cs

    else:
        l_params_f32 = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
            layout.l_shapes,
        )
        opt_local = jax.eval_shape(
            partial(zero1_init, base_opt, n_dp=layout.n_dp), l_params_f32
        )
        opt_specs = zero1_state_specs(
            opt_local, layout.dp_spec, model_axis=layout.model_axis
        )

        from repro.optim.zero1 import shard_leaf

        def init_fn(params):
            dp_index = layout.ctx.worker_index()
            masters_full = jax.tree.map(
                lambda p: shard_leaf(p, layout.n_dp), params
            )
            my = jax.tree.map(
                lambda m: lax.dynamic_slice_in_dim(m, dp_index, 1, 0),
                masters_full,
            )
            base_state = base_opt.init(jax.tree.map(lambda m: m[0], my))
            restack = lambda t: jax.tree.map(
                lambda x: x[None] if x.ndim >= 1 else x, t
            )
            opt_state = {"master": my, "base": restack(base_state)}
            cs = compressor.init(params)
            cs = jax.tree.map(lambda x: jnp.asarray(x)[None], cs)
            return opt_state, cs

    return _sharded(
        layout, init_fn, (layout.pspecs,), (opt_specs, comp_specs)
    )


# ---------------------------------------------------------------------------
# eval step (loss-only — validation / perplexity sweeps)
# ---------------------------------------------------------------------------
def build_eval_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    param_dtype=jnp.bfloat16,
) -> StepArtifacts:
    """Forward-only loss over the mesh: the train body's forward stage with
    aggregation/update routing stripped."""
    from repro.launch.inputs import input_specs

    layout = resolve_layout(
        cfg, mesh, param_dtype=param_dtype, remap_tp1=True
    )
    loss_fn = _loss_fn_for(cfg)

    batch_struct = input_specs(cfg, shape, kind="train")
    batch_specs = specs_mod.batch_pspecs(batch_struct, dp=layout.dp)

    def body(params, batch):
        loss = loss_fn(params, batch, layout.axes, layout.cfg, dtype=jnp.bfloat16)
        return coll.psum(loss, layout.dp) / layout.n_dp

    in_specs = (layout.pspecs, batch_specs)
    jitted = _sharded(layout, body, in_specs, P())
    return StepArtifacts(
        jitted={"eval": jitted},
        arg_structs=(layout.g_shapes, batch_struct),
        in_shardings=coll.named_shardings(mesh, in_specs),
        out_shardings=None,
        abstract_state=None,
    )


# ---------------------------------------------------------------------------
# serve steps (prefill / decode)
# ---------------------------------------------------------------------------
def build_serve_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    param_dtype=jnp.bfloat16,
) -> StepArtifacts:
    from repro.launch.inputs import input_specs

    layout = resolve_layout(cfg, mesh, param_dtype=param_dtype)
    dp, dp_sizes, n_dp, tp = layout.dp, layout.dp_sizes, layout.n_dp, layout.tp
    seq_sharded = shape.kind == "decode" and shape.global_batch < n_dp
    if seq_sharded:
        axes = Axes(tp="model", tp_size=tp, sp=dp, sp_sizes=dp_sizes)
        b_local = shape.global_batch
        s_local = shape.seq_len // n_dp
    else:
        axes = Axes(tp="model", tp_size=tp)
        b_local = max(1, shape.global_batch // n_dp)
        s_local = shape.seq_len

    if shape.kind == "prefill":
        batch_struct = input_specs(cfg, shape, kind="prefill")
        batch_specs = specs_mod.batch_pspecs(batch_struct, dp=dp)

        def prefill(params, batch):
            if cfg.family == "encdec":
                h = encdec_encode(params, batch["frames"], axes, cfg)
                logits = jnp.einsum(
                    "btd,dv->btv", h[:, -1:], params["lm_head"].astype(h.dtype)
                ).astype(jnp.float32)[:, 0]
            else:
                h = lm_forward(params, batch, axes, cfg)
                logits = lm_logits_local(params, h[:, -1:], cfg)[:, 0]
            return logits

        in_specs = (layout.pspecs, batch_specs)
        out_specs = P(layout.dp_spec, "model")
        jitted = _sharded(
            layout, prefill, in_specs, out_specs, shard_outputs=False
        )
        arg_structs = (layout.g_shapes, batch_struct)
        return StepArtifacts(
            jitted={"prefill": jitted},
            arg_structs=arg_structs,
            in_shardings=coll.named_shardings(mesh, in_specs),
            out_shardings=None,
            abstract_state=None,
        )

    # decode
    cache_local = specs_mod.cache_shapes(
        cfg, tp, tp, b_local, s_local, s_src=min(shape.seq_len, 32768)
    )
    cache_specs = specs_mod.cache_pspecs(
        cache_local, dp=dp, seq_sharded=seq_sharded
    )

    def to_global(struct, spec):
        shape_l = list(struct.shape)
        for i, p in enumerate(spec):
            if p is None:
                continue
            size = tp if p == "model" else n_dp
            shape_l[i] = shape_l[i] * size
        return jax.ShapeDtypeStruct(tuple(shape_l), struct.dtype)

    cache_global = jax.tree.map(
        to_global, cache_local, cache_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    tok_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tok_spec = P() if seq_sharded else P(layout.dp_spec)

    def decode(params, cache, tokens, pos):
        if cfg.family == "encdec":
            logits, new_cache = encdec_decode_step(
                params, cache, tokens, pos, axes, cfg
            )
        else:
            logits, new_cache = lm_decode_step(params, cache, tokens, pos, axes, cfg)
        next_tok = tp_greedy(logits, axes)
        return next_tok, new_cache

    in_specs = (layout.pspecs, cache_specs, tok_spec, tok_spec)
    out_specs = (tok_spec, cache_specs)
    jitted = _sharded(layout, decode, in_specs, out_specs, donate=(1,))
    arg_structs = (layout.g_shapes, cache_global, tok_struct, pos_struct)
    return StepArtifacts(
        jitted={"decode": jitted},
        arg_structs=arg_structs,
        in_shardings=coll.named_shardings(mesh, in_specs),
        out_shardings=coll.named_shardings(mesh, out_specs),
        abstract_state=None,
    )
