"""PartitionSpec inference.

Parameter specs are DERIVED, not hand-written: the model's init functions
take ``n_shards`` ∈ {1, tp}; we eval_shape both and diff the shapes — the
dimension that differs by exactly ×tp is the `model`-sharded one. This keeps
the sharding table mechanically in sync with the model code.

Cache/batch specs follow fixed per-leaf-name conventions (documented below),
with leading stacked-layer axes auto-skipped.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.stats import TreeDims
from repro.models.decode import init_lm_cache
from repro.models.encdec import init_encdec_cache, init_encdec_params
from repro.models.transformer import init_lm_params


def _init_fn(cfg: ModelConfig):
    return init_encdec_params if cfg.family == "encdec" else init_lm_params


def param_shapes(cfg: ModelConfig, tp: int, n_shards: int):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        partial(_init_fn(cfg), cfg=cfg, tp=tp, n_shards=n_shards), key
    )


def infer_param_specs(cfg: ModelConfig, tp: int, model_axis: str = "model"):
    """Returns (global_shapes, local_shapes, pspecs)."""
    g = param_shapes(cfg, tp, 1)
    l = param_shapes(cfg, tp, tp)

    def spec(gl, lo):
        if gl.shape == lo.shape:
            return P()
        diff = [
            i
            for i, (a, b) in enumerate(zip(gl.shape, lo.shape))
            if a != b
        ]
        if len(diff) != 1 or gl.shape[diff[0]] != lo.shape[diff[0]] * tp:
            raise ValueError(f"ambiguous sharding: {gl.shape} vs {lo.shape}")
        parts = [None] * len(gl.shape)
        parts[diff[0]] = model_axis
        return P(*parts)

    pspecs = jax.tree.map(spec, g, l)
    return g, l, pspecs


def global_tree_dims(cfg: ModelConfig, tp: int) -> TreeDims:
    """GLOBAL model dimensionality (for α's d and blockwise d_l) with the
    same tree structure as the LOCAL parameter shards."""
    g = param_shapes(cfg, tp, 1)
    leaf_dims = jax.tree.map(lambda x: float(jnp.prod(jnp.array(x.shape))), g)
    import numpy as np

    d = int(sum(np.prod(x.shape) for x in jax.tree.leaves(g)))
    return TreeDims(d=d, leaf_dims=jax.tree.map(float, leaf_dims))


# ---------------------------------------------------------------------------
# cache specs: by leaf name, with leading stacked-layer axes skipped
# ---------------------------------------------------------------------------
_CACHE_BASE = {
    # name: (ndim-without-stacking, batch_dim, seq_dim, model_dim)
    "k": (4, 0, 1, 2),
    "v": (4, 0, 1, 2),
    "kv_pos": (2, 0, 1, None),
    "pos": (2, 0, 1, None),
    "c_kv": (3, 0, 1, None),
    "k_r": (3, 0, 1, None),
    "conv": (3, 0, None, 2),
    "h": (None, 0, None, 1),  # mamba state (B,H,N,P) or slstm (B,H,dh)
    "C": (4, 0, None, 1),
    "n": (3, 0, None, 1),
    "c": (3, 0, None, 1),
}


def cache_pspecs(cache_tree, *, dp: tuple, seq_sharded: bool, model_axis="model"):
    """dp: data-parallel axis name tuple, e.g. ("pod","data").
    seq_sharded=True (long_500k): the KV sequence dim carries `dp` and the
    batch dim is replicated; recurrent-state leaves stay replicated over dp."""
    dp_spec = dp if len(dp) > 1 else dp[0]

    def leaf_spec(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        if name not in _CACHE_BASE:
            raise ValueError(f"no cache rule for leaf {path}")
        ndim_base, b_dim, s_dim, m_dim = _CACHE_BASE[name]
        ndim_base = ndim_base or leaf.ndim  # "h" appears with 3 or 4 dims
        extra = leaf.ndim - ndim_base
        # count only genuine stacking prefixes
        parts = [None] * leaf.ndim
        if seq_sharded:
            if s_dim is not None:
                parts[extra + s_dim] = dp_spec
            # batch=1: replicated over dp
        else:
            parts[extra + b_dim] = dp_spec
        if m_dim is not None:
            parts[extra + m_dim] = model_axis
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def batch_pspecs(batch_tree, *, dp: tuple, seq_sharded: bool = False):
    dp_spec = dp if len(dp) > 1 else dp[0]

    def leaf_spec(leaf):
        if seq_sharded:
            return P(*([None] * leaf.ndim))  # batch=1 decode: replicated
        return P(*([dp_spec] + [None] * (leaf.ndim - 1)))

    return jax.tree.map(leaf_spec, batch_tree)


def cache_shapes(cfg: ModelConfig, tp, n_shards, b, s, s_src=None):
    if cfg.family == "encdec":
        fn = partial(
            init_encdec_cache, cfg, tp, n_shards, b, s, s_src or s
        )
    else:
        fn = partial(init_lm_cache, cfg, tp, n_shards, b, s)
    return jax.eval_shape(fn)
