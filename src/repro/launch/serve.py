"""Serving driver: batched decode demo on a smoke-scale model.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch, smoke_config
from repro.models.transformer import init_lm_params
from repro.parallel.collectives import mesh_from_counts
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", action="store_true",
                    help="run decode through the shard_map pipeline on a "
                         "1x1 mesh (the sharded-serve lowering path)")
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    mesh = mesh_from_counts(data=1, model=1) if args.mesh else None
    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=128, mesh=mesh)
    key = jax.random.PRNGKey(1)
    for r in range(args.requests):
        k = jax.random.fold_in(key, r)
        prompt = list(
            jax.random.randint(k, (4 + r % 4,), 0, cfg.vocab).tolist()
        )
        eng.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    t0 = time.time()
    iters = eng.run()
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"[serve] {args.requests} requests, {iters} engine iterations, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s, "
          f"continuous batching over {args.slots} slots)")


if __name__ == "__main__":
    main()
