"""ShapeDtypeStruct stand-ins for every model input (the dry-run contract:
weak-type-correct, shardable, no device allocation).

For [vlm]/[audio] archs the modality frontend is a STUB: input_specs provides
precomputed patch/frame embeddings of the documented frontend width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def input_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str = "train"):
    b, t = shape.global_batch, shape.seq_len
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    bf16 = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)

    if cfg.family == "encdec":
        batch = {"frames": bf16(b, t, cfg.frontend_dim)}
        if kind == "train":
            # teacher-forced target length = source length (documented choice)
            batch["tokens"] = i32(b, t)
            batch["labels"] = i32(b, t)
        return batch

    t_text = t - cfg.n_frontend_tokens if cfg.frontend == "vit" else t
    batch = {"tokens": i32(b, t_text)}
    if cfg.frontend == "vit":
        batch["patch_embeds"] = bf16(b, cfg.n_frontend_tokens, cfg.frontend_dim)
    if kind == "train":
        batch["labels"] = i32(b, t_text)
    return batch


def materialize_batch(cfg: ModelConfig, shape: ShapeConfig, key, kind="train"):
    """Concrete random batch with the input_specs structure (smoke/demo)."""
    structs = input_specs(cfg, shape, kind)

    def mk(path, s):
        name = path[-1].key
        if s.dtype == jnp.int32:
            return jax.random.randint(key, s.shape, 0, cfg.vocab)
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype)

    return jax.tree_util.tree_map_with_path(mk, structs)
