"""IntSGD core: integer rounding, adaptive scaling, compressors, aggregation."""
from repro.core.comm import CommCtx, fold_worker_key
from repro.core.compressor import (
    Compressor,
    HeuristicIntSGD,
    IntDIANA,
    IntSGD,
    Metrics,
    NatSGD,
    NoCompression,
    PowerSGD,
    QSGD,
    SignSGD,
    TopK,
    WireAggregate,
    aggregate_exact,
    make_compressor,
    with_wire,
)
from repro.core.rounding import (
    decode,
    deterministic_round,
    encode,
    int_round,
    stochastic_round,
)
from repro.core.scaling import (
    AlphaBlockwise,
    AlphaDiana,
    AlphaHeuristic,
    AlphaLastStep,
    AlphaMovingAvg,
    AlphaRule,
    AlphaState,
    make_alpha_rule,
)
