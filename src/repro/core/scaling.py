"""Scaling-factor rules for IntSGD (paper §4 + Appendix A.1).

All rules return α (scalar, or one scalar per block) from replicated
optimizer state — *no communication* is ever needed to agree on α, which is
the property that makes integer all-reduce possible.

Implemented rules:

  * ``AlphaMovingAvg`` (Alg. 1 / Prop. 2, the paper's default):
        r_k = β r_{k-1} + (1-β) ||x^k - x^{k-1}||²
        α_k = sqrt(d) / sqrt(2 n r_k / η_k² + ε²)

  * ``AlphaLastStep`` (Prop. 3): β = 0, ε = 0 special case
        α_k = η_k sqrt(d) / (sqrt(2n) ||x^k - x^{k-1}||)

  * ``AlphaBlockwise`` (Alg. 2 / Prop. 4): per-block
        α_{k,l} = η_k sqrt(d_l) / sqrt(2 n r_{k,l} + η_k² (d_l/d) ε²)

  * ``AlphaHeuristic`` (Sapio et al. 2021, the SwitchML baseline):
        α = (2^nb - 1) / (n · 2^max_exp)
    where max_exp is the rounded exponent of the largest |coordinate| in the
    package — this requires a profiling max-reduce across workers (the extra
    collective the paper criticizes; we surface it via `needs_profiling`).

  * ``AlphaDiana`` (Thm 4): α_k = η_k sqrt(d) / (sqrt(n) ||x^k - x^{k-1}||)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp



@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AlphaState:
    """Replicated state carried by the scaling rule across steps."""

    r: Any  # scalar (global rules) or pytree of per-block scalars
    step: jax.Array  # int32 scalar


class AlphaRule:
    """Interface: init() -> state;  update(state, dx_stats) -> state;
    alpha(state, eta, n, d) -> α. ``dx_stats`` is a DxStats of GLOBAL
    ||Δx||² values (the step function reduces over TP shards first)."""

    needs_profiling: bool = False

    def init(self, params) -> AlphaState:
        raise NotImplementedError

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        raise NotImplementedError

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AlphaMovingAvg(AlphaRule):
    """Paper default: β=0.9, ε=1e-8 (Alg. 1)."""

    beta: float = 0.9
    eps: float = 1e-8

    def init(self, params) -> AlphaState:
        return AlphaState(r=jnp.zeros((), jnp.float32), step=jnp.zeros((), jnp.int32))

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        r = self.beta * state.r + (1.0 - self.beta) * dx_stats.sq
        return AlphaState(r=r, step=state.step + 1)

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        denom = jnp.sqrt(2.0 * n_workers * state.r / jnp.square(eta) + self.eps**2)
        return jnp.sqrt(jnp.asarray(d, jnp.float32)) / denom


@dataclasses.dataclass(frozen=True)
class AlphaLastStep(AlphaRule):
    """Prop. 3: α_k = η_k √d / (√(2n) ||Δx||); ε=0, β=0."""

    def init(self, params) -> AlphaState:
        return AlphaState(r=jnp.zeros((), jnp.float32), step=jnp.zeros((), jnp.int32))

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        return AlphaState(r=dx_stats.sq, step=state.step + 1)

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        return (
            eta
            * jnp.sqrt(jnp.asarray(d, jnp.float32))
            / (jnp.sqrt(2.0 * n_workers) * jnp.sqrt(state.r) + 1e-30)
        )


@dataclasses.dataclass(frozen=True)
class AlphaBlockwise(AlphaRule):
    """Alg. 2: one α per pytree leaf (block = layer tensor).

    α_{k,l} = η_k √d_l / sqrt(2 n r_{k,l} + η_k² (d_l/d) ε²).
    The returned α is a pytree matching the gradient structure.
    """

    beta: float = 0.9
    eps: float = 1e-8

    def init(self, params) -> AlphaState:
        r = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), params)
        return AlphaState(r=r, step=jnp.zeros((), jnp.int32))

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        def upd(r, sq):
            return self.beta * r + (1.0 - self.beta) * sq

        return AlphaState(
            r=jax.tree.map(upd, state.r, dx_stats.leaf_sq), step=state.step + 1
        )

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        def a(r, leaf_r):
            del leaf_r
            return r

        def per_block(r_l, d_l):
            denom = jnp.sqrt(
                2.0 * n_workers * r_l
                + jnp.square(eta) * (d_l / d) * self.eps**2
            )
            return eta * jnp.sqrt(jnp.asarray(d_l, jnp.float32)) / (denom + 1e-30)

        # block dims are static, derived from the r-tree structure at trace time
        # by the caller supplying matching leaves; here we carry them via shape
        # metadata attached in `alpha_tree`.
        raise NotImplementedError("use alpha_tree(state, eta, n, dims_tree)")

    def alpha_tree(self, state: AlphaState, eta, n_workers: int, dims_tree, d: int):
        def per_block(r_l, d_l):
            denom = jnp.sqrt(
                2.0 * n_workers * r_l + jnp.square(eta) * (d_l / d) * self.eps**2
            )
            return eta * jnp.sqrt(jnp.asarray(d_l, jnp.float32)) / (denom + 1e-30)

        return jax.tree.map(per_block, state.r, dims_tree)


@dataclasses.dataclass(frozen=True)
class AlphaHeuristic(AlphaRule):
    """SwitchML / Sapio et al. (2021) profiling rule (baseline, not convergent).

    α = (2^nb - 1) / (n · 2^max_exp), max_exp = ceil(log2 max_i |v_i|) over the
    *global* package — the caller must supply the globally-maxed |v| (we expose
    `needs_profiling=True`; the distributed aggregator inserts a pmax).
    """

    bits: int = 8
    needs_profiling: bool = True

    def init(self, params) -> AlphaState:
        return AlphaState(r=jnp.zeros((), jnp.float32), step=jnp.zeros((), jnp.int32))

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        return AlphaState(r=state.r, step=state.step + 1)

    def alpha_from_absmax(self, global_absmax, n_workers: int):
        max_exp = jnp.ceil(jnp.log2(jnp.maximum(global_absmax, 1e-30)))
        return (2.0 ** (self.bits - 1) - 1.0) / (n_workers * jnp.exp2(max_exp))

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        raise NotImplementedError("heuristic rule needs the profiled absmax")


@dataclasses.dataclass(frozen=True)
class AlphaDiana(AlphaRule):
    """Thm 4 rule for IntDIANA: α_k = η √d / (√n ||Δx||)."""

    def init(self, params) -> AlphaState:
        return AlphaState(r=jnp.zeros((), jnp.float32), step=jnp.zeros((), jnp.int32))

    def update(self, state: AlphaState, dx_stats) -> AlphaState:
        return AlphaState(r=dx_stats.sq, step=state.step + 1)

    def alpha(self, state: AlphaState, eta, n_workers: int, d: int):
        return (
            eta
            * jnp.sqrt(jnp.asarray(d, jnp.float32))
            / (jnp.sqrt(1.0 * n_workers) * jnp.sqrt(state.r) + 1e-30)
        )


def make_alpha_rule(name: str, **kw) -> AlphaRule:
    rules = {
        "moving_avg": AlphaMovingAvg,
        "last_step": AlphaLastStep,
        "blockwise": AlphaBlockwise,
        "heuristic": AlphaHeuristic,
        "diana": AlphaDiana,
    }
    if name not in rules:
        raise ValueError(f"unknown alpha rule {name!r}; options {sorted(rules)}")
    return rules[name](**kw)
