"""Gradient compressors: IntSGD (ours, all variants) + the paper's baselines.

Every compressor implements::

    init(params)                         -> state (replicated pytree)
    aggregate(state, grads, key, eta, ctx) -> (ghat, new_state, metrics)

where ``grads`` is the *local* gradient pytree of one worker and ``ctx`` is a
:class:`repro.core.comm.CommCtx`. ``ghat`` is the aggregated (averaged)
gradient estimate, identical on every worker. ``metrics`` reports wire
statistics (max |integer| on the wire, estimated bits/coordinate, payload
bytes) used by tests and the paper-table benchmarks.

Aggregation semantics per family:

  * all-reduce compatible (IntSGD, Heuristic IntSGD, PowerSGD, SignSGD, none):
    the payload is *summed* across workers in one psum — unless the
    configured wire codec declares a gather transport (TopKInt's value+index
    planes), in which case ``CommCtx.psum_wire`` all-gathers the integer
    payload and the codec's unpack performs the sum by scatter-add;
  * all-gather only (QSGD, NatSGD, TopK): payloads are gathered and each
    worker decodes all n of them — the expensive path the paper's Tables 2/3
    quantify; our roofline benchmark reproduces that comparison from HLO
    collective bytes.

IntSGD state-update split: α depends on r_k, which depends on the *model
update* of the previous step. The optimizer wrapper calls
``observe_update(state, delta_x)`` after applying the step; ``aggregate`` only
reads the current state. The first optimization step must use exact
aggregation (paper §4.1 "the first communication is exact") — drivers call
``aggregate_exact`` at k=0 and the compressed step thereafter.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, ClassVar, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel import collectives as coll

from repro.core.comm import CommCtx, fold_worker_key
from repro.core.stats import DxStats, TreeDims, local_tree_dims
from repro.wire import DenseInt, WireFormat, make_wire_format
from repro.core.scaling import (
    AlphaBlockwise,
    AlphaDiana,
    AlphaHeuristic,
    AlphaLastStep,
    AlphaMovingAvg,
    AlphaRule,
)
from repro.utils.tree import tree_abs_max, tree_size, tree_sq_norm


def _leaf_dims(params):
    return jax.tree.map(lambda x: float(x.size), params)


def aggregate_exact(grads, ctx: CommCtx):
    """Full-precision mean over workers (step-0 / no-compression path)."""
    return ctx.pmean(grads)


def _leaf_keys(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    return jax.tree.unflatten(treedef, list(jax.random.split(key, len(leaves))))


def _payload_bytes(wf: WireFormat, tree) -> float:
    """Static per-worker collective payload under codec `wf` (exact bytes)."""
    return float(sum(wf.wire_bytes(l.size) for l in jax.tree.leaves(tree)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WireAggregate:
    """What came back from the integer all-reduce.

    ``words`` is the summed transport payload exactly as it crossed the
    wire (packed int32 words / narrow lanes) — the fused Pallas update
    consumes it directly. ``ints`` is the unpacked summed integer image
    Σ_i Int(α g_i) (canonical int32) for decode, clipping and metrics; XLA
    fuses its unpack into whatever reduction consumes it, so keeping both
    views costs no extra HBM traffic on the fused route.
    """

    words: Any
    ints: Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Metrics:
    max_int: jax.Array  # max |aggregated integer| on the wire (0 for float paths)
    bits_per_coord: jax.Array  # estimated wire bits per coordinate
    payload_bytes: float = dataclasses.field(
        metadata=dict(static=True)
    )  # static: bytes sent per worker per step
    # max over workers of the LOCAL payload |Int(α g_i)|∞ — the per-worker
    # wire-width requirement; this is the quantity that blows up for IntGD on
    # heterogeneous data and that IntDIANA bounds (Appendix A.2 / Fig. 6)
    max_local_int: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.zeros(())
    )


class Compressor:
    supports_allreduce: ClassVar[bool] = True
    name: ClassVar[str] = "base"
    # --- fused/wire-level capability (the compressor half of the fused-route
    # contract; the optimizer half is Optimizer.fused_kernel) ----------------
    # fused_capable: the compressor exposes its aggregation at WIRE level —
    # ``encode_ints`` (per-image encode, microbatch pipelining),
    # ``aggregate_wire`` (encode+reduce without decoding, the fused Pallas
    # entry), ``finish_pipelined`` (decode + state advance of accumulated
    # images) and the shift hooks below. launch/step.py routes the fused
    # update AND the microbatch wire pipelining on this flag alone.
    fused_capable: ClassVar[bool] = False
    # fused_local_state: state updates consume the LOCAL integer image
    # (IntDIANA's h_local); the pipelined train body accumulates it only
    # when this is set.
    fused_local_state: ClassVar[bool] = False

    def init(self, params) -> Any:
        return ()

    def observe_update(self, state, dx_stats: DxStats):
        """Called by the optimizer after x^{k+1} = x^k - η ĝ with the GLOBAL
        ||Δx||² statistics (see repro.core.stats)."""
        return state

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        raise NotImplementedError

    # --- fused-route shift hooks (no-ops unless the compressor carries a
    # replicated shift the decode must add, like IntDIANA's h_global) -------
    def fused_shift(self, state):
        """Replicated global-shift tree the fused kernel adds to the decoded
        aggregate (g = shift + Σints/(nα)), or None."""
        return None

    def fused_store_shift(self, state, new_shift):
        """Fold the kernel's emitted shift output back into the state."""
        return state


# --------------------------------------------------------------------------
# Full precision (the SGD baseline; also what step 0 of IntSGD uses)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NoCompression(Compressor):
    name: ClassVar[str] = "none"
    # all-gather flavour exists purely to reproduce the paper's
    # SGD (All-gather) row; semantics are identical.
    use_allgather: bool = False

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        d = tree_size(grads)
        if self.use_allgather:
            gathered = ctx.all_gather(grads)
            ghat = jax.tree.map(lambda g: jnp.mean(g, axis=0), gathered)
            payload = 4.0 * d * ctx.n
        else:
            ghat = ctx.pmean(grads)
            payload = 4.0 * d
        m = Metrics(jnp.zeros(()), jnp.full((), 32.0), payload)
        return ghat, state, m


# --------------------------------------------------------------------------
# IntSGD (ours) — global / blockwise α, stochastic / deterministic rounding
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IntSGD(Compressor):
    """Algorithm 1 (global α) / Algorithm 2 (blockwise α).

    The transport representation is delegated to a :class:`WireFormat`
    (``wire``); ``bits``/``use_kernels`` are the legacy shorthand for the
    dense codec and are folded into the default ``DenseInt`` when no codec
    is given explicitly.

    Sparse (gather-transport) codecs drop coordinates, so IntSGD carries an
    EF21-style error-feedback residual for them: the state becomes
    ``{"alpha": AlphaState, "ef": residual tree}``, each step encodes
    ``work = grad + residual`` and feeds back
    ``residual' = work − local_image/α`` — exactly the per-worker decode
    error, quantization and sparsification both. Lossless (psum) codecs
    keep the bare AlphaState and an identical trajectory to before.
    """

    name: ClassVar[str] = "intsgd"
    alpha_rule: AlphaRule = AlphaMovingAvg()
    bits: int = 32
    stochastic: bool = True
    use_kernels: bool = False  # route encode/pack through Pallas kernels
    wire: WireFormat | None = None

    @property
    def fused_capable(self) -> bool:  # type: ignore[override]
        """Delegates to the codec: the fused decode+update route (and the
        microbatch wire pipelining built on it) needs the wire's fused
        kernel, which sparse codecs don't have."""
        return bool(getattr(self.wire_format, "fused_capable", True))

    @property
    def blockwise(self) -> bool:
        return isinstance(self.alpha_rule, AlphaBlockwise)

    @property
    def wire_format(self) -> WireFormat:
        if self.wire is not None:
            return self.wire
        return DenseInt(bits=self.bits, use_kernels=self.use_kernels)

    @property
    def _carries_residual(self) -> bool:
        return getattr(self.wire_format, "transport", "psum") == "gather"

    @staticmethod
    def _split_state(state):
        """State -> (alpha_state, residual | None)."""
        if isinstance(state, dict) and set(state) == {"alpha", "ef"}:
            return state["alpha"], state["ef"]
        return state, None

    def init(self, params):
        alpha = self.alpha_rule.init(params)
        if self._carries_residual:
            ef = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            return {"alpha": alpha, "ef": ef}
        return alpha

    def observe_update(self, state, dx_stats: DxStats):
        alpha, ef = self._split_state(state)
        alpha = self.alpha_rule.update(alpha, dx_stats)
        if ef is not None:
            return {"alpha": alpha, "ef": ef}
        return alpha

    def _alphas(self, state, grads, eta, n, dims: TreeDims | None):
        if dims is None:
            dims = local_tree_dims(grads)
        if self.blockwise:
            a = self.alpha_rule.alpha_tree(
                state, eta, n, dims.leaf_dims, float(dims.d)
            )
        else:
            a_scalar = self.alpha_rule.alpha(state, eta, n, dims.d)
            a = jax.tree.map(lambda _: a_scalar, grads)
        return a

    def encode_ints(
        self, state, grads, *, key, eta, ctx: CommCtx, dims=None,
        n_accum: int = 1,
    ):
        """One worker's §5.1-clipped integer image Int(α∘x) and the α tree —
        the encode stage alone, no wire traffic. The overlapped train body
        (launch/step.py microbatch pipelining) calls this per microbatch so
        each image's bucketed reduce can launch while the next microbatch's
        backward is still running; ``aggregate_wire`` is the single-shot
        encode+reduce composition.

        ``n_accum`` is how many summed images the caller will ACCUMULATE on
        top of the n-worker wire sum (M for M-microbatch pipelining): the
        clip tightens to ``clip_limit(n·n_accum)`` so the full accumulated
        sum still fits the value width — without it an int32 wire with
        M > 1 could wrap the int32 accumulator on clip-saturating
        gradients. The transport itself still packs/unpacks with n (only n
        payloads ride each psum), which the tighter clip keeps safe.

        When the codec is sparse the encoded tensor is ``work = grad +
        residual`` (error feedback); the residual advance itself lives in
        ``aggregate_wire`` — the pipelined path never reaches here with a
        sparse codec because its ``fused_capable`` is False."""
        n = ctx.n
        wf = self.wire_format
        alpha_state, ef = self._split_state(state)
        work = grads
        if ef is not None:
            work = jax.tree.map(
                lambda g, r: g.astype(jnp.float32) + r, grads, ef
            )
        alphas = self._alphas(alpha_state, work, eta, n, dims)
        akeys = _leaf_keys(fold_worker_key(key, ctx), work)
        ints = jax.tree.map(
            lambda g, a, k: wf.encode(
                g, a, k, n_workers=n * n_accum, stochastic=self.stochastic
            ),
            work,
            alphas,
            akeys,
        )
        return ints, alphas

    def aggregate_wire(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        """Wire-level aggregation: returns the summed wire payload (packed
        words + integer image, see :class:`WireAggregate`) and the α tree
        *without* decoding. This is the entry point the fused decode+update
        kernel routing (launch/step.py) builds on — the decode 1/(nα) is
        folded into the Pallas optimizer kernel instead of materializing ĝ.
        ``aggregate`` is the decode-here wrapper."""
        n = ctx.n
        wf = self.wire_format
        ints, alphas = self.encode_ints(
            state, grads, key=key, eta=eta, ctx=ctx, dims=dims
        )
        max_local = coll.pmax(tree_abs_max(ints), ctx.axes)
        # THE wire: codec-packed integer aggregation. On TPU this is the ICI
        # collective carrying only integer transport planes — the paper's
        # INA/all-reduce analog, at bits/8 bytes per coordinate for the
        # packed codec, or the gathered vals+idx planes for sparse ones.
        words_sum, int_sum = ctx.psum_wire(ints, wf)
        alpha_state, ef = self._split_state(state)
        if ef is not None:
            # EF21 advance: what the wire dropped (or rounded away) of this
            # worker's work tensor is carried into the next step. local_image
            # re-derives the transmitted selection from the same ints —
            # XLA CSEs it against pack's top_k, so no second selection runs.
            work = jax.tree.map(
                lambda g, r: g.astype(jnp.float32) + r, grads, ef
            )
            local = jax.tree.map(
                lambda v: wf.local_image(v, n_workers=n), ints
            )
            ef = jax.tree.map(
                lambda w, l, a: w - l.astype(jnp.float32) / a,
                work, local, alphas,
            )
            state = {"alpha": alpha_state, "ef": ef}
        max_int = tree_abs_max(int_sum)
        bits = 1.0 + jnp.ceil(jnp.log2(jnp.maximum(max_int, 1.0) + 1.0))
        payload = _payload_bytes(wf, grads)
        return (
            WireAggregate(words=words_sum, ints=int_sum),
            alphas,
            state,
            Metrics(max_int, bits, payload, max_local),
        )

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        wa, alphas, state, metrics = self.aggregate_wire(
            state, grads, key=key, eta=eta, ctx=ctx, dims=dims
        )
        wf = self.wire_format
        ghat = jax.tree.map(
            lambda s, a: wf.decode(s, a, n_workers=ctx.n), wa.ints, alphas
        )
        return ghat, state, metrics

    def finish_pipelined(
        self, state, int_sum_acc, local_int_acc, alphas, *, ctx: CommCtx,
        n_accum: int,
    ):
        """Decode the n_accum accumulated summed images of the microbatch-
        pipelined train body: ĝ = (1/(n·M·α)) Σ_m Σ_i Int(α g_i^m). The
        per-image clip (``encode_ints(n_accum=M)``) guarantees the int32
        accumulator never wrapped. IntSGD carries no wire-level state, so
        ``local_int_acc`` (None here — fused_local_state is False) is
        unused and the state passes through."""
        del local_int_acc
        wf = self.wire_format
        ghat = jax.tree.map(
            lambda s, a: wf.decode(s, a, n_workers=ctx.n * n_accum),
            int_sum_acc,
            alphas,
        )
        return ghat, state


# --------------------------------------------------------------------------
# Heuristic IntSGD (Sapio et al. 2021) — profiling max-reduce, fixed α
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HeuristicIntSGD(Compressor):
    name: ClassVar[str] = "heuristic_intsgd"
    bits: int = 8
    stochastic: bool = False
    wire: WireFormat | None = None

    @property
    def wire_format(self) -> WireFormat:
        return self.wire if self.wire is not None else DenseInt(bits=self.bits)

    def init(self, params):
        return ()

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        n = ctx.n
        wf = self.wire_format
        rule = AlphaHeuristic(bits=self.bits)
        local_absmax = jnp.max(
            jnp.stack([jnp.max(jnp.abs(l)) for l in jax.tree.leaves(grads)])
        )
        # the profiling step: an extra float max-reduce before every round —
        # this is exactly the overhead the paper's adaptive rule removes.
        global_absmax = ctx.pmax_global(local_absmax)
        alpha = rule.alpha_from_absmax(global_absmax, n)
        akeys = _leaf_keys(fold_worker_key(key, ctx), grads)
        # The heuristic α bounds |αg| <= (2^(b-1)-1)/n, but rounding can
        # nudge a coordinate one past that bound — and neither a packed
        # field nor a narrow dense lane has any slack for the n-worker sum
        # (4 workers at 32 when α said 31.75 wraps an int8 psum). So the
        # hard §5.1 sum-clip applies on every codec; it only bites in the
        # rounding-nudge case the α bound already aimed to exclude.
        ints = jax.tree.map(
            lambda g, k: wf.encode(
                g, alpha, k, n_workers=n, stochastic=self.stochastic
            ),
            grads,
            akeys,
        )
        _, int_sum = ctx.psum_wire(ints, wf)
        ghat = jax.tree.map(lambda s: wf.decode(s, alpha, n_workers=n), int_sum)
        max_int = tree_abs_max(int_sum)
        bits = 1.0 + jnp.ceil(jnp.log2(jnp.maximum(max_int, 1.0) + 1.0))
        return ghat, state, Metrics(max_int, bits, _payload_bytes(wf, grads))


# --------------------------------------------------------------------------
# QSGD (Alistarh et al. 2017) — all-gather only
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """QSGD with an optional wire codec for the gathered integer payload.

    With ``wire=None`` this is the paper-faithful transport: one int8 level
    lane + one int8 sign lane per coordinate. With a codec, the signed level
    v = sign·q ∈ [-levels, levels] rides the codec's transport words instead
    (all-gather, so pack/unpack use n_workers=1 — no sum crosses the wire);
    PackedInt(8) halves the gathered bytes vs the two-lane layout.
    """

    name: ClassVar[str] = "qsgd"
    supports_allreduce: ClassVar[bool] = False
    levels: int = 64  # 6-bit, matching the paper's setup
    wire: WireFormat | None = None

    def init(self, params):
        return ()

    def _quantize_leaf(self, g, key):
        norm = jnp.linalg.norm(g.astype(jnp.float32).reshape(-1)) + 1e-30
        scaled = jnp.abs(g.astype(jnp.float32)) / norm * self.levels
        lo = jnp.floor(scaled)
        p = scaled - lo
        u = jax.random.uniform(key, g.shape, dtype=jnp.float32)
        q = lo + (u < p).astype(jnp.float32)
        return q, norm.astype(jnp.float32)

    def _encode_leaf(self, g, key):
        q, norm = self._quantize_leaf(g, key)
        return q.astype(jnp.int8), jnp.sign(g).astype(jnp.int8), norm

    @property
    def _bits_per_coord(self) -> float:
        """Wire bits per coordinate: level field + sign."""
        return 1.0 + math.ceil(math.log2(self.levels + 1))

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        akeys = _leaf_keys(fold_worker_key(key, ctx), grads)
        is_shaped = lambda x: hasattr(x, "shape")
        if self.wire is not None:
            wf = self.wire
            if getattr(wf, "transport", "psum") == "gather":
                raise ValueError(
                    "QSGD's gathered level payload needs a psum-shaped "
                    "(dense/packed) codec; a gather-transport codec like "
                    f"{wf.name!r} cannot carry it"
                )
            if wf.clip_limit(1) < self.levels:
                raise ValueError(
                    f"wire bits={wf.bits} too narrow for {self.levels} levels"
                )

            def enc(g, k):
                q, norm = self._quantize_leaf(g, k)
                v = (q * jnp.sign(g.astype(jnp.float32))).astype(jnp.int32)
                return wf.pack(v, n_workers=1), norm

            enc_tree = jax.tree.map(enc, grads, akeys, is_leaf=is_shaped)
            gathered = ctx.all_gather(enc_tree)

            def dec(leaf, g_like):
                words, norm = leaf
                vals = jax.vmap(
                    lambda w: wf.unpack(w, g_like.shape, n_summed=1)
                )(words).astype(jnp.float32)
                vals = vals * (
                    norm.reshape((-1,) + (1,) * g_like.ndim) / self.levels
                )
                return jnp.mean(vals, axis=0)

            ghat = jax.tree.map(
                dec, gathered, grads, is_leaf=lambda x: isinstance(x, tuple)
            )
            payload = _payload_bytes(wf, grads) + 4.0 * len(jax.tree.leaves(grads))
            return ghat, state, Metrics(
                jnp.zeros(()), jnp.full((), self._bits_per_coord), payload
            )

        enc = jax.tree.map(self._encode_leaf, grads, akeys, is_leaf=is_shaped)
        # all-gather of (levels, signs, norm): the expensive primitive
        gathered = ctx.all_gather(enc)

        def dec(leaf):
            q, s, norm = leaf
            vals = q.astype(jnp.float32) * s.astype(jnp.float32)
            vals = vals * (norm.reshape((-1,) + (1,) * (q.ndim - 1)) / self.levels)
            return jnp.mean(vals, axis=0)

        ghat = jax.tree.map(dec, gathered, is_leaf=lambda x: isinstance(x, tuple))
        d = tree_size(grads)
        # entropy-coded estimate: level bits + sign bit + norms, per worker
        payload = d * (self._bits_per_coord + 2.0) / 8.0
        return ghat, state, Metrics(
            jnp.zeros(()), jnp.full((), self._bits_per_coord), payload
        )


# --------------------------------------------------------------------------
# NatSGD — natural compression (Horváth et al. 2019), all-gather only
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NatSGD(Compressor):
    name: ClassVar[str] = "natsgd"
    supports_allreduce: ClassVar[bool] = False

    def init(self, params):
        return ()

    def _encode_leaf(self, g, key):
        g = g.astype(jnp.float32)
        mag = jnp.abs(g)
        safe = jnp.maximum(mag, 1e-38)
        e_lo = jnp.floor(jnp.log2(safe))
        p_up = mag / jnp.exp2(e_lo) - 1.0  # prob of rounding exponent up
        u = jax.random.uniform(key, g.shape, dtype=jnp.float32)
        e = e_lo + (u < p_up).astype(jnp.float32)
        e = jnp.where(mag == 0, -127.0, e)
        return jnp.clip(e, -126.0, 126.0).astype(jnp.int8), jnp.sign(g).astype(jnp.int8)

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        wkey = fold_worker_key(key, ctx)
        leaves, treedef = jax.tree.flatten(grads)
        akeys = jax.tree.unflatten(treedef, list(jax.random.split(wkey, len(leaves))))
        enc = jax.tree.map(self._encode_leaf, grads, akeys, is_leaf=lambda x: hasattr(x, "shape"))
        gathered = ctx.all_gather(enc)

        def dec(leaf):
            e, s = leaf
            vals = jnp.where(
                e.astype(jnp.float32) <= -127.0,
                0.0,
                jnp.exp2(e.astype(jnp.float32)) * s.astype(jnp.float32),
            )
            return jnp.mean(vals, axis=0)

        ghat = jax.tree.map(dec, gathered, is_leaf=lambda x: isinstance(x, tuple))
        d = tree_size(grads)
        return ghat, state, Metrics(jnp.zeros(()), jnp.full((), 9.0), d * 1.125)


# --------------------------------------------------------------------------
# PowerSGD (Vogels et al. 2019) + error feedback — all-reduce compatible
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PowerSGD(Compressor):
    name: ClassVar[str] = "powersgd"
    rank: int = 2
    ef: bool = True
    min_compress_size: int = 4096  # small tensors stay uncompressed (float psum)

    def _is_matrix(self, x):
        return x.ndim >= 2 and x.size >= self.min_compress_size

    def init(self, params):
        def q_init(x):
            if not self._is_matrix(x):
                return None
            m = x.reshape(x.shape[0], -1)
            k = jax.random.PRNGKey(abs(hash(str(m.shape))) % (2**31))
            return jax.random.normal(k, (m.shape[1], self.rank), jnp.float32)

        qs = jax.tree.map(q_init, params)
        errs = jax.tree.map(jnp.zeros_like, params) if self.ef else None
        return {"q": qs, "err": errs}

    @staticmethod
    def _orthonormalize(p):
        # modified Gram-Schmidt, numerically adequate for small ranks
        q, _ = jnp.linalg.qr(p)
        return q

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        n = ctx.n
        errs = state["err"]
        work = (
            jax.tree.map(jnp.add, grads, errs) if self.ef else grads
        )

        def comp(m, q):
            if q is None:
                return None
            m2 = m.reshape(m.shape[0], -1).astype(jnp.float32)
            p = m2 @ q  # (rows, rank)
            p = coll.psum(p, ctx.axes) / n  # all-reduce #1 (small!)
            p_hat = self._orthonormalize(p)
            qn = m2.T @ p_hat  # (cols, rank)
            qn = coll.psum(qn, ctx.axes) / n  # all-reduce #2
            approx = (p_hat @ qn.T).reshape(m.shape)
            return approx, qn

        q_leaf = lambda x: x is None
        outs = jax.tree.map(
            lambda m, q: comp(m, q), work, state["q"], is_leaf=q_leaf
        )
        # `outs` leaves are (approx, qn) tuples or None — stop traversal there
        o_leaf = lambda x: x is None or (
            isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "shape")
        )

        def pick_ghat(m, o):
            if o is None:
                return coll.psum(m, ctx.axes) / n  # uncompressed small tensors
            return o[0]

        def pick_q(o, q_old):
            return q_old if o is None else o[1]

        ghat = jax.tree.map(pick_ghat, work, outs, is_leaf=o_leaf)
        new_q = jax.tree.map(pick_q, outs, state["q"], is_leaf=o_leaf)
        if self.ef:
            new_err = jax.tree.map(
                lambda w, g, o: jnp.zeros_like(w) if o is None else (w - g),
                work,
                ghat,
                outs,
                is_leaf=o_leaf,
            )
        else:
            new_err = None
        d = tree_size(grads)
        return (
            ghat,
            {"q": new_q, "err": new_err},
            Metrics(jnp.zeros(()), jnp.full((), 32.0), 4.0 * d * 0.05),
        )


# --------------------------------------------------------------------------
# SignSGD + EF (Karimireddy et al. 2019) — scaled sign, all-reduce of int8
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SignSGD(Compressor):
    name: ClassVar[str] = "signsgd"
    ef: bool = True

    def init(self, params):
        return jax.tree.map(jnp.zeros_like, params) if self.ef else ()

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        n = ctx.n
        work = jax.tree.map(jnp.add, grads, state) if self.ef else grads

        def comp(w):
            w32 = w.astype(jnp.float32)
            scale = jnp.mean(jnp.abs(w32))  # ||w||_1 / d
            signs = jnp.sign(w32).astype(jnp.int8)
            local = scale * signs.astype(jnp.float32)  # C(p_i), what worker i sends
            # wire: int8 sign psum + one scalar psum (all-reduce compatible)
            ghat_leaf = coll.psum(local, ctx.axes) / n
            return ghat_leaf, local

        outs = jax.tree.map(comp, work)
        ghat = jax.tree.map(lambda o: o[0], outs, is_leaf=lambda x: isinstance(x, tuple))
        # EF uses each worker's OWN compressed output: e_i' = p_i - C(p_i)
        new_state = (
            jax.tree.map(
                lambda w, o: w - o[1],
                work,
                outs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            if self.ef
            else ()
        )
        d = tree_size(grads)
        return ghat, new_state, Metrics(jnp.zeros(()), jnp.full((), 1.0), d / 8.0)


# --------------------------------------------------------------------------
# Top-K + EF — all-gather of (values, indices)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    name: ClassVar[str] = "topk"
    supports_allreduce: ClassVar[bool] = False
    k_frac: float = 0.01
    ef: bool = True

    def init(self, params):
        return jax.tree.map(jnp.zeros_like, params) if self.ef else ()

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        n = ctx.n
        work = jax.tree.map(jnp.add, grads, state) if self.ef else grads

        def comp(w):
            flat = w.astype(jnp.float32).reshape(-1)
            k = max(1, int(self.k_frac * flat.size))
            _, idx = lax.top_k(jnp.abs(flat), k)
            vals = flat[idx]
            local = jnp.zeros_like(flat).at[idx].set(vals)  # C(p_i)
            g_vals = ctx.all_gather(vals)  # (n, k)
            g_idx = ctx.all_gather(idx)  # (n, k)
            out = jnp.zeros_like(flat)
            out = out.at[g_idx.reshape(-1)].add(g_vals.reshape(-1))
            return (out / n).reshape(w.shape), local.reshape(w.shape)

        outs = jax.tree.map(comp, work)
        ghat = jax.tree.map(lambda o: o[0], outs, is_leaf=lambda x: isinstance(x, tuple))
        new_state = (
            jax.tree.map(
                lambda w, o: w - o[1],
                work,
                outs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            if self.ef
            else ()
        )
        d = tree_size(grads)
        return ghat, new_state, Metrics(
            jnp.zeros(()), jnp.full((), 32.0 * self.k_frac * 2), 8.0 * d * self.k_frac
        )


# --------------------------------------------------------------------------
# IntDIANA (Algorithm 3) — compress gradient differences with local shifts
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IntDIANA(Compressor):
    """Algorithm 3. Local shift h_i lives on each worker (it is NOT replicated
    across the data axes — in the distributed runtime it is per-device state);
    the global shift h is replicated. Fixes the heterogeneous-data max-int
    blowup of plain IntSGD (Appendix A.2, Fig. 6).

    Wire-level split (fused_capable): ``aggregate_wire`` encodes the
    difference image Int(α(g_i - h_i)), advances h_local off that LOCAL
    image and reduces — WITHOUT decoding or touching h_global. The decode
    ĝ = h_global + (1/(nα))Σints then happens either here (``aggregate``) or
    inside the fused Pallas kernel, which takes h_global as its ``shift``
    input and emits the new h_global (= ĝ) alongside p'/moments in the same
    HBM pass (``fused_shift`` / ``fused_store_shift``).
    """

    name: ClassVar[str] = "intdiana"
    fused_local_state: ClassVar[bool] = True  # h_local reads the local image
    alpha_rule: AlphaRule = AlphaDiana()
    bits: int = 32
    stochastic: bool = True
    wire: WireFormat | None = None

    @property
    def fused_capable(self) -> bool:  # type: ignore[override]
        """Delegates to the codec, like IntSGD: the fused route and the
        microbatch pipelining need the wire's fused decode+update kernel."""
        return bool(getattr(self.wire_format, "fused_capable", True))

    @property
    def wire_format(self) -> WireFormat:
        return self.wire if self.wire is not None else DenseInt(bits=self.bits)

    def init(self, params):
        zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return {
            "alpha": self.alpha_rule.init(params),
            "h_local": zeros,  # per-worker (lives under the data axes)
            "h_global": zeros,  # replicated
        }

    def observe_update(self, state, dx_stats: DxStats):
        return dict(state, alpha=self.alpha_rule.update(state["alpha"], dx_stats))

    def _alphas(self, state, grads, eta, n, dims: TreeDims | None):
        d = dims.d if dims is not None else tree_size(grads)
        a_scalar = self.alpha_rule.alpha(state["alpha"], eta, n, d)
        return jax.tree.map(lambda _: a_scalar, grads)

    def encode_ints(
        self, state, grads, *, key, eta, ctx: CommCtx, dims=None,
        n_accum: int = 1,
    ):
        """One worker's difference image Int(α(g - h_i)) and the α tree.
        Every image carries the FULL local shift: with ``n_accum=M``
        (microbatch pipelining) the accumulated sum is
        Σ_m Int(α(g^m - h_i)) ≈ α(Σ_m g^m - M·h_i), so the 1/(n·M·α)
        decode recovers ḡ - h̄ exactly as the single-shot round does —
        diluting the shift per image (h_i/M) would leave an h̄·(1-1/M)
        bias in ĝ and drift h_local toward M·ḡ. The clip tightens to the
        full n·M sum exactly as for IntSGD. h_local is NOT advanced here —
        that happens in ``aggregate_wire`` (single-shot) or
        ``finish_pipelined`` (accumulated), off the same integer
        image(s)."""
        n = ctx.n
        wf = self.wire_format
        alphas = self._alphas(state, grads, eta, n, dims)
        akeys = _leaf_keys(fold_worker_key(key, ctx), grads)
        ints = jax.tree.map(
            lambda g, h, a, k: wf.encode(
                g.astype(jnp.float32) - h, a, k,
                n_workers=n * n_accum, stochastic=self.stochastic,
            ),
            grads,
            state["h_local"],
            alphas,
            akeys,
        )
        return ints, alphas

    def aggregate_wire(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        """Encode + h_local advance + integer all-reduce, no decode: the
        fused-route entry point (launch/step.py feeds the returned words and
        ``fused_shift(state)`` to the Pallas kernel)."""
        n = ctx.n
        wf = self.wire_format
        ints, alphas = self.encode_ints(
            state, grads, key=key, eta=eta, ctx=ctx, dims=dims
        )
        max_local = coll.pmax(tree_abs_max(ints), ctx.axes)
        # local shift: h_i += Q(g_i - h_i) = (1/α) Int(α (g_i - h_i))
        h_local = jax.tree.map(
            lambda h, s, a: h + s.astype(jnp.float32) / a,
            state["h_local"], ints, alphas,
        )
        words_sum, int_sum = ctx.psum_wire(ints, wf)
        max_int = tree_abs_max(int_sum)
        bits = 1.0 + jnp.ceil(jnp.log2(jnp.maximum(max_int, 1.0) + 1.0))
        return (
            WireAggregate(words=words_sum, ints=int_sum),
            alphas,
            dict(state, h_local=h_local),
            Metrics(max_int, bits, _payload_bytes(wf, grads), max_local),
        )

    def aggregate(self, state, grads, *, key, eta, ctx: CommCtx, dims=None):
        wa, alphas, state, metrics = self.aggregate_wire(
            state, grads, key=key, eta=eta, ctx=ctx, dims=dims
        )
        wf = self.wire_format
        mean_q = jax.tree.map(
            lambda s, a: wf.decode(s, a, n_workers=ctx.n), wa.ints, alphas
        )
        h_global = jax.tree.map(jnp.add, state["h_global"], mean_q)
        # ĝ = h + mean Q(g_i - h_i) == the advanced global shift
        return h_global, dict(state, h_global=h_global), metrics

    def finish_pipelined(
        self, state, int_sum_acc, local_int_acc, alphas, *, ctx: CommCtx,
        n_accum: int,
    ):
        """Accumulated-image decode + shift advance:
        mean_q = (1/(n·M·α)) ΣΣ ints, h_i += (1/(M·α)) Σ_m ints_i^m,
        ĝ = h_global + mean_q (= new h_global)."""
        wf = self.wire_format
        h_local = jax.tree.map(
            lambda h, s, a: h + s.astype(jnp.float32) / (n_accum * a),
            state["h_local"], local_int_acc, alphas,
        )
        mean_q = jax.tree.map(
            lambda s, a: wf.decode(s, a, n_workers=ctx.n * n_accum),
            int_sum_acc,
            alphas,
        )
        h_global = jax.tree.map(jnp.add, state["h_global"], mean_q)
        return h_global, dict(state, h_local=h_local, h_global=h_global)

    def fused_shift(self, state):
        return state["h_global"]

    def fused_store_shift(self, state, new_shift):
        return dict(state, h_global=new_shift)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def with_wire(comp: Compressor, wire) -> Compressor:
    """Rebind a compressor to a wire codec (name string or WireFormat)."""
    wire = make_wire_format(wire)
    fields = {f.name for f in dataclasses.fields(comp)}
    if "wire" not in fields:
        raise ValueError(
            f"compressor {comp.name!r} has no wire-codec seam (only the "
            "integer-wire families are codec-configurable)"
        )
    if "bits" in fields and comp.bits != wire.bits:
        # the codec's width wins in encode(); a silent mismatch would train
        # a different recipe than the compressor name claims
        raise ValueError(
            f"wire codec is {wire.bits}-bit but compressor {comp.name!r} "
            f"was built with bits={comp.bits}; construct them consistently "
            f"(e.g. make_compressor('{comp.name}', bits={wire.bits}, "
            f"wire=...))"
        )
    if "use_kernels" in fields and comp.use_kernels:
        # keep the Pallas routing the compressor asked for: the kernel and
        # jnp encode paths use different (equally valid) stochastic-rounding
        # streams, so silently dropping the flag would change the trajectory
        if dataclasses.is_dataclass(wire):
            if not wire.use_kernels:
                wire = dataclasses.replace(wire, use_kernels=True)
        elif dataclasses.is_dataclass(getattr(wire, "inner", None)):
            # metering wrapper (Logged): propagate into the wrapped codec so
            # the instrumented run meters the SAME trajectory it wraps
            if not wire.inner.use_kernels:
                wire.inner = dataclasses.replace(
                    wire.inner, use_kernels=True
                )
    return dataclasses.replace(comp, wire=wire)


def make_compressor(name: str, **kw) -> Compressor:
    from repro.wire import PackedInt

    reg = {
        "none": NoCompression,
        "allgather_sgd": partial(NoCompression, use_allgather=True),
        "intsgd": IntSGD,
        "intsgd_determ": partial(IntSGD, stochastic=False),
        "intsgd_block": partial(IntSGD, alpha_rule=AlphaBlockwise()),
        "intsgd4": partial(IntSGD, bits=4),
        "intsgd8": partial(IntSGD, bits=8),
        # bit-packed transport words instead of one lane per coordinate
        "intsgd8_packed": partial(IntSGD, bits=8, wire=PackedInt(bits=8)),
        "intsgd4_packed": partial(IntSGD, bits=4, wire=PackedInt(bits=4)),
        "heuristic_intsgd": HeuristicIntSGD,
        "qsgd": QSGD,
        "natsgd": NatSGD,
        "powersgd": PowerSGD,
        "signsgd": SignSGD,
        "topk": TopK,
        "intdiana": IntDIANA,
    }
    if name not in reg:
        raise ValueError(f"unknown compressor {name!r}; options {sorted(reg)}")
    if "wire" in kw and kw["wire"] is not None:
        kw = dict(kw)
        wire = kw.pop("wire")
        return with_wire(reg[name](**kw), wire)  # bits-consistency checked
    return reg[name](**kw)
