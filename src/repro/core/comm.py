"""Communication context: one abstraction for real meshes and simulated workers.

Compressors and aggregators are written against ``CommCtx`` only. The same
code path then runs:

  * inside ``shard_map`` over the production mesh (axes = ("pod","data") or
    ("data",)) — collectives lower to real ICI all-reduce / all-gather;
  * inside ``vmap(axis_name="workers")`` — the n-worker simulation
    used by CPU convergence tests and the paper-reproduction benchmarks.

This is what lets us validate the *distributed algorithm* bit-exactly on a
single CPU device and then lower the identical code for 512 chips. All raw
collectives come from :mod:`repro.parallel.collectives`, the version-portable
layer both execution modes share.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

from repro.parallel import collectives as coll
from repro.wire import bucketing


@dataclasses.dataclass(frozen=True)
class CommCtx:
    axes: Tuple[str, ...]  # mesh/vmap axis names holding the data-parallel workers
    axis_sizes: Tuple[int, ...]
    model_axis: str | None = None  # TP axis (for global profiling reductions)
    # overlapped-wire configuration (PR 3): "off" = one monolithic psum of
    # the whole transport tree (the serial reference); "ring" = fixed-size
    # word buckets, each an independent ppermute ring reduce-scatter +
    # all-gather, so XLA can hide bucket k's wire time behind pending
    # compute. Bit-identical decode either way (integer sums are exact).
    overlap: str = "off"
    bucket_words: int = bucketing.DEFAULT_BUCKET_WORDS

    def __post_init__(self):
        if self.overlap not in ("off", "ring"):
            raise ValueError(
                f"unknown overlap mode {self.overlap!r}; options ('off', 'ring')"
            )

    @property
    def n(self) -> int:
        out = 1
        for s in self.axis_sizes:
            out *= s
        return out

    def psum(self, x):
        return coll.psum_tree(x, self.axes)

    def psum_wire(self, ints, wf):
        """Codec-aware integer aggregation: pack each leaf with the wire
        format `wf` into its transport payload (≥1 integer planes), move the
        payload across the data-parallel axes with the collective shape the
        codec declares (the ONLY thing that crosses the wire), and unpack
        back to the summed integer image. Returns ``(words_sum, int_sum)``
        — the fused update route consumes the words directly, everything
        else the image.

        ``wf.transport == "psum"`` (dense/packed) sums the word plane on the
        wire. With ``overlap="ring"`` the words are cut into fixed-size
        buckets (repro.wire.bucketing) and each bucket ring-reduced
        independently; the debucketized word sums are bit-identical to the
        serial psum's, so everything downstream (decode, fused kernels,
        parity tests) is agnostic to which transport ran.

        ``wf.transport == "gather"`` (sparse codecs) all-gathers the payload
        instead — a value is only meaningful next to its index plane, so no
        sum is legal on the wire — and unpack performs the sum by
        scatter-add. The gather route always rides the bucketed layout (one
        bucket when overlap is off, ``bucket_words``-sized buckets under
        "ring" so the gathers interleave with pending compute); the returned
        ``words_sum`` holds the gathered planes with a leading worker axis.
        """
        if getattr(wf, "transport", "psum") == "gather":
            return self._gather_wire(ints, wf)
        words = jax.tree.map(
            lambda v: wf.pack(v, n_workers=self.n), ints
        )
        if self.overlap == "ring":
            manifest = bucketing.plan_buckets(
                words, bucket_words=self.bucket_words
            )
            buckets = bucketing.bucketize(words, manifest)
            buckets_sum = coll.psum_wire_words_bucketed(
                buckets, self.axes, self.axis_sizes
            )
            words_sum = bucketing.debucketize(buckets_sum, manifest)
        else:
            words_sum = coll.psum_wire_words(words, self.axes)
        int_sum = jax.tree.map(
            lambda w, v: wf.unpack(w, v.shape, n_summed=self.n),
            words_sum,
            ints,
        )
        return words_sum, int_sum

    def _gather_wire(self, ints, wf):
        """The gather-shaped transport (see :meth:`psum_wire`)."""
        payload = jax.tree.map(
            lambda v: wf.pack(v, n_workers=self.n), ints
        )
        total = sum(l.size for l in jax.tree.leaves(payload))
        bucket_words = (
            self.bucket_words if self.overlap == "ring" else max(total, 1)
        )
        manifest = bucketing.plan_buckets(payload, bucket_words=bucket_words)
        buckets = bucketing.bucketize(payload, manifest)
        gathered_buckets = coll.allgather_wire_words(
            buckets, self.axes, self.axis_sizes
        )
        gathered = bucketing.debucketize_gathered(gathered_buckets, manifest)
        int_sum = jax.tree.map(
            lambda v, p: wf.unpack(p, v.shape, n_summed=self.n),
            ints,
            gathered,
        )
        return gathered, int_sum

    def pmax(self, x):
        return coll.pmax_tree(x, self.axes)

    def pmax_global(self, x):
        """Max over workers AND TP shards (profiling reductions that must see
        the entire model, e.g. Heuristic IntSGD's max_exp). When tp==1 the
        layout folds the model axis into the data-parallel axes (remap_tp1),
        so only append it when it is not already a worker axis."""
        extra = (
            (self.model_axis,)
            if self.model_axis and self.model_axis not in self.axes
            else ()
        )
        return coll.pmax_tree(x, self.axes + extra)

    def pmean(self, x):
        return coll.pmean_tree(x, self.axes, self.n)

    def all_gather(self, x):
        """Gather with a flat leading worker axis of size n."""
        return jax.tree.map(
            lambda v: coll.all_gather_flat(v, self.axes, self.n), x
        )

    def worker_index(self):
        """Linearized data-parallel worker id in [0, n)."""
        return coll.linear_axis_index(self.axes, self.axis_sizes)


def fold_worker_key(key: jax.Array, ctx: CommCtx) -> jax.Array:
    """Independent rounding randomness per worker (required for the 1/n
    variance averaging in Lemma 2's proof — quantization errors must be
    independent across workers)."""
    return jax.random.fold_in(key, ctx.worker_index())
