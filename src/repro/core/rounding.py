"""Randomized and deterministic integer rounding — the paper's Int operator.

    Int(t) = floor(t) + 1  with prob  t - floor(t)
             floor(t)      otherwise                       (paper §2)

Properties (Lemma 1, verified by tests/test_rounding.py):
    E[Int(t)] = t
    E[(Int(t) - t)^2] <= 1/4      (Bernoulli variance bound)

The float-domain quantizer is  Q(x) = (1/α) ∘ Int(α ∘ x)  (eq. 2). In the
distributed algorithm the *integer* image Int(α ∘ x) is what crosses the wire;
Q is only materialized after aggregation.

Overflow safety: the paper clips local integers so that the *sum over n
workers* fits the wire dtype (int8 or int32): |Int(α g_i)| <= (2^(b-1)-1)/n.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# The §5.1 range/limit primitives are owned by the wire subsystem (they
# define what the transport can carry); re-exported here for the scalar-lane
# reference path and back-compat. repro.wire deliberately has no module-level
# core imports, so this direction is cycle-free.
from repro.wire.base import (  # noqa: F401  (re-exports)
    _INT_RANGE,
    WireRangeError,
    clip_limit as _wire_clip_limit,
)


def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """Randomized rounding to the nearest integers, unbiased (float dtype out)."""
    x = x.astype(jnp.float32)
    lo = jnp.floor(x)
    p = x - lo
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return lo + (u < p).astype(jnp.float32)


def deterministic_round(x: jax.Array) -> jax.Array:
    """Round-half-even (`torch.round` analogue) — the IntSGD (Determ.) variant."""
    return jnp.round(x.astype(jnp.float32))


def int_round(
    x: jax.Array,
    key: jax.Array | None,
    *,
    stochastic: bool = True,
) -> jax.Array:
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return stochastic_round(x, key)
    return deterministic_round(x)


def clip_limit(*, n_workers: int, bits: int) -> int:
    """The §5.1 clip limit: largest |v| such that the n-worker sum fits
    `bits`. Raises :class:`WireRangeError` when the limit degenerates to 0
    (the n-worker sum cannot be represented at all) instead of silently
    zeroing every gradient coordinate. Canonical impl: repro.wire.base."""
    return _wire_clip_limit(n_workers=n_workers, bits=bits)


def clip_for_wire(ints: jax.Array, *, n_workers: int, bits: int) -> jax.Array:
    """Clip local integers so the n-worker sum fits the wire dtype (paper §5.1)."""
    lim = clip_limit(n_workers=n_workers, bits=bits)
    return jnp.clip(ints, -lim, lim)


def wire_dtype(bits: int):
    """Narrowest native integer lane that holds one `bits`-wide value."""
    return {4: jnp.int8, 8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[bits]


def encode(
    x: jax.Array,
    alpha: jax.Array,
    key: jax.Array | None,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
) -> jax.Array:
    """x -> Int(α ∘ x), clipped to the wire range, in the wire integer dtype.

    Contract: the result is transported in the NARROWEST native lane that
    holds one `bits`-wide value (int8 for bits<=8, int16, int32 — see
    :func:`wire_dtype`), and the §5.1 clip guarantees the n-worker SUM still
    fits `bits`, so an all-reduce of the returned array is overflow-safe in
    its own lane dtype. This is the reference scalar-lane transport; the
    bit-packed transport (sub-words coded into int32 lanes) lives in
    :mod:`repro.wire` and shares this clip.
    """
    r = int_round(x.astype(jnp.float32) * alpha, key, stochastic=stochastic)
    r = clip_for_wire(r, n_workers=n_workers, bits=bits)
    return r.astype(wire_dtype(bits))


def decode(ints: jax.Array, alpha: jax.Array, *, n_workers: int) -> jax.Array:
    """Aggregated integers -> gradient estimate: (1/(n α)) ∘ Σ_i Int(α g_i)."""
    return ints.astype(jnp.float32) / (n_workers * alpha)
