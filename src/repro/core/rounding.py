"""Randomized and deterministic integer rounding — the paper's Int operator.

    Int(t) = floor(t) + 1  with prob  t - floor(t)
             floor(t)      otherwise                       (paper §2)

Properties (Lemma 1, verified by tests/test_rounding.py):
    E[Int(t)] = t
    E[(Int(t) - t)^2] <= 1/4      (Bernoulli variance bound)

The float-domain quantizer is  Q(x) = (1/α) ∘ Int(α ∘ x)  (eq. 2). In the
distributed algorithm the *integer* image Int(α ∘ x) is what crosses the wire;
Q is only materialized after aggregation.

Overflow safety: the paper clips local integers so that the *sum over n
workers* fits the wire dtype (int8 or int32): |Int(α g_i)| <= (2^(b-1)-1)/n.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INT_RANGE = {8: 127, 16: 32767, 32: 2147483647}


def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """Randomized rounding to the nearest integers, unbiased (float dtype out)."""
    x = x.astype(jnp.float32)
    lo = jnp.floor(x)
    p = x - lo
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return lo + (u < p).astype(jnp.float32)


def deterministic_round(x: jax.Array) -> jax.Array:
    """Round-half-even (`torch.round` analogue) — the IntSGD (Determ.) variant."""
    return jnp.round(x.astype(jnp.float32))


def int_round(
    x: jax.Array,
    key: jax.Array | None,
    *,
    stochastic: bool = True,
) -> jax.Array:
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return stochastic_round(x, key)
    return deterministic_round(x)


def clip_for_wire(ints: jax.Array, *, n_workers: int, bits: int) -> jax.Array:
    """Clip local integers so the n-worker sum fits the wire dtype (paper §5.1)."""
    if bits not in _INT_RANGE:
        raise ValueError(f"unsupported wire width {bits}")
    lim = _INT_RANGE[bits] // max(n_workers, 1)
    return jnp.clip(ints, -lim, lim)


def wire_dtype(bits: int):
    return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[bits]


def encode(
    x: jax.Array,
    alpha: jax.Array,
    key: jax.Array | None,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
) -> jax.Array:
    """x -> Int(α ∘ x), clipped to the wire range, in the wire integer dtype.

    NOTE: aggregation must be performed in a dtype wide enough for the sum;
    we always *transport* int32 on the TPU wire (psum) but value-range-clip to
    the configured `bits` so the experiment semantics (int8 vs int32 runs of
    the paper) are preserved.
    """
    r = int_round(x.astype(jnp.float32) * alpha, key, stochastic=stochastic)
    r = clip_for_wire(r, n_workers=n_workers, bits=bits)
    # transport in the narrow wire dtype: the clip above guarantees the
    # n-worker SUM still fits `bits`, so the all-reduce itself runs in int8/
    # int16 — this is where the 4x/2x communication win materializes.
    return r.astype(wire_dtype(bits))


def decode(ints: jax.Array, alpha: jax.Array, *, n_workers: int) -> jax.Array:
    """Aggregated integers -> gradient estimate: (1/(n α)) ∘ Σ_i Int(α g_i)."""
    return ints.astype(jnp.float32) / (n_workers * alpha)
