"""n-worker distributed training simulated on one device via vmap(axis_name).

This executes the *identical* compressor code that runs under shard_map on
the production mesh (same psum/all-gather collectives, same per-worker RNG
folding), so CPU convergence experiments validate the distributed algorithm,
not a reimplementation.

Used by: tests/test_convergence.py, tests/test_diana.py,
benchmarks/bench_convergence.py, examples/logreg_diana.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.comm import CommCtx
from repro.core.compressor import Compressor, aggregate_exact
from repro.core.stats import local_dx_stats, scale_dx_stats
from repro.optim.base import Optimizer, apply_updates
from repro.parallel import collectives as coll
from repro.utils.tree import tree_sub

AXIS = coll.WORKER_AXIS


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    params: Any  # replicated
    opt_state: Any  # replicated
    comp_state: Any  # leading worker axis n on every leaf
    step: jax.Array
    key: jax.Array


class SimTrainer:
    """loss_fn(params, batch) -> scalar loss. Batches carry a leading worker
    axis: batch[i] is worker i's minibatch (heterogeneous data supported)."""

    def __init__(
        self,
        loss_fn: Callable,
        n_workers: int,
        compressor: Compressor,
        optimizer: Optimizer,
        lr_schedule: Callable,
    ):
        self.loss_fn = loss_fn
        self.n = n_workers
        self.comp = compressor
        self.opt = optimizer
        self.lr = lr_schedule
        self.ctx = CommCtx(axes=(AXIS,), axis_sizes=(n_workers,))
        self._step_exact = jax.jit(partial(self._step, exact=True))
        self._step_comp = jax.jit(partial(self._step, exact=False))

    def init(self, params, key=None) -> SimState:
        key = key if key is not None else jax.random.PRNGKey(0)
        comp_state = self.comp.init(params)
        # broadcast compressor state across the worker axis
        comp_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n,) + jnp.shape(x)), comp_state
        )
        return SimState(
            params=params,
            opt_state=self.opt.init(params),
            comp_state=comp_state,
            step=jnp.zeros((), jnp.int32),
            key=key,
        )

    # ---- one worker's view of a round (runs under vmap with axis_name) ----
    def _worker_round(self, params, comp_state, batch_i, key, eta, exact: bool):
        grads = jax.grad(self.loss_fn)(params, batch_i)
        if exact:
            ghat = aggregate_exact(grads, self.ctx)
            new_cs, metrics = comp_state, None
        else:
            ghat, new_cs, metrics = self.comp.aggregate(
                comp_state, grads, key=key, eta=eta, ctx=self.ctx
            )
        return ghat, new_cs, metrics, grads

    def _step(self, state: SimState, batches, *, exact: bool):
        key, sub = jax.random.split(state.key)
        eta = self.lr(state.step)
        round_fn = coll.vmap_workers(
            partial(self._worker_round, exact=exact),
            in_axes=(None, 0, 0, None, None),
        )
        ghat_all, new_cs, metrics, _ = round_fn(
            state.params, state.comp_state, batches, sub, eta
        )
        # ghat is identical on every worker by construction; take worker 0
        ghat = jax.tree.map(lambda x: x[0], ghat_all)
        updates, opt_state = self.opt.update(ghat, state.opt_state, state.params, eta)
        new_params = apply_updates(state.params, updates)
        # Δx^{k+1} = x^{k+1} - x^k feeds r_{k+1} (moving average, Alg. 1 line 6),
        # rescaled to gradient-equivalent units (§4.1: momentum-inclusive
        # update, dx_scale = 1-μ corrects the 1/(1-μ) amplification)
        dx_stats = scale_dx_stats(local_dx_stats(updates), self.opt.dx_scale)
        if jax.tree.leaves(new_cs):
            new_cs = jax.vmap(self.comp.observe_update, in_axes=(0, None))(
                new_cs, dx_stats
            )
        out_metrics = None
        if metrics is not None:
            out_metrics = jax.tree.map(
                lambda x: x[0] if hasattr(x, "ndim") and x.ndim > 0 else x, metrics
            )
        return (
            SimState(new_params, opt_state, new_cs, state.step + 1, key),
            out_metrics,
        )

    def step(self, state: SimState, batches):
        """First round is exact (paper §4.1), later rounds compressed."""
        if int(state.step) == 0:
            return self._step_exact(state, batches)
        return self._step_comp(state, batches)
