"""Model-update statistics feeding the adaptive α rules.

Under tensor parallelism the quantities in Alg. 1 are GLOBAL: d is the full
model dimension and r_k tracks the global ||Δx||². Each TP shard computes its
local contribution and the step function psums over the model axis before
handing the stats to the compressor — so every device derives the *same* α
with zero extra communication beyond two scalars per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel import collectives as coll

from repro.utils.tree import tree_size


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DxStats:
    """||Δx||² statistics (already reduced to global values)."""

    sq: jax.Array  # scalar ||Δx||²
    leaf_sq: Any  # pytree of per-leaf ||Δx_l||² (for blockwise α)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TreeDims:
    """Global dimensionality of the model (static)."""

    d: int = dataclasses.field(metadata=dict(static=True))
    leaf_dims: Any = dataclasses.field(metadata=dict(static=True))  # pytree of ints


def local_dx_stats(delta_x) -> DxStats:
    leaf_sq = jax.tree.map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), delta_x
    )
    sq = jnp.sum(jnp.stack(jax.tree.leaves(leaf_sq))) if jax.tree.leaves(leaf_sq) else jnp.zeros(())
    return DxStats(sq=sq, leaf_sq=leaf_sq)


def local_tree_dims(tree) -> TreeDims:
    leaf_dims = jax.tree.map(lambda x: float(x.size), tree)
    return TreeDims(d=tree_size(tree), leaf_dims=leaf_dims)


def scale_dx_stats(stats: DxStats, scale: float) -> DxStats:
    """Rescale ||Δx||² stats by scale² — converts the applied (momentum-
    amplified) update into the gradient-equivalent displacement the α rules
    expect (scale = Optimizer.dx_scale, e.g. 1-μ for heavy-ball SGD)."""
    if scale == 1.0:
        return stats
    s2 = scale * scale
    return DxStats(
        sq=stats.sq * s2,
        leaf_sq=jax.tree.map(lambda v: v * s2, stats.leaf_sq),
    )


def psum_stats(stats: DxStats, axis: Optional[str]) -> DxStats:
    if axis is None:
        return stats
    return DxStats(
        sq=coll.psum(stats.sq, axis),
        leaf_sq=jax.tree.map(lambda s: coll.psum(s, axis), stats.leaf_sq),
    )
