"""Pallas TPU kernels for IntSGD's compute hot-spots.

The paper's SwitchML predecessor spends measurable wall-clock on
compression/decompression (Tables 2-3 "Computation Overhead" column); on TPU
we fuse those element-wise chains into three kernels so the gradient tensor
crosses HBM once per stage:

  int_compress   g, α, seed          -> Int(α∘g) clipped   (1 read, 1 write)
  fused_update   Σints, p, m, scalars -> p', m'            (3 reads, 2 writes,
                 replacing the naive dequant→wd→momentum→axpy chain that
                 would read/write HBM 9 times)
  block_norms    x -> per-block ||x_l||²                   (for blockwise α)
  wire_pack      image <-> bit-packed int32 transport words (PackedInt wire;
                 fused_unpack_update consumes the words directly so the
                 unpacked image never touches HBM — see repro/wire/packed.py)

Randomness is a counter-based hash PRNG (fmix32 finalizer) computed in plain
jnp ops: identical bits under interpret=True (CPU validation) and Mosaic
(TPU), and reproduced exactly by the pure-jnp oracle in ref.py.
"""
