"""Pallas kernels: bit-pack / unpack PackedInt transport words.

Mirrors ``int_compress_2d``'s tiling: a 2-D grid over a (rows, cols) view of
the WORD array, blocks (BM, BN) with BN a multiple of 128 and BM a multiple
of 8. The integer image rides along as a (k, rows, cols) view — field j of
word (r, c) is image element (j, r, c) — so each grid step is one VMEM pass:
read k sub-blocks + write one word block (pack), or the reverse (unpack).

Field arithmetic is plain int32 with wrap-around (mod 2^32) semantics:
pack adds bias-shifted fields (never carrying across field boundaries by the
§5.1 clip — see repro/wire/packed.py for the invariant), unpack extracts
with arithmetic-shift + mask (sign-extension only touches masked-off bits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 1024)


def _pack_kernel(x_ref, o_ref, *, k, bits, lim):
    x = x_ref[...]  # (k, bm, bn) int32
    word = x[0] + lim
    for j in range(1, k):
        word = word + ((x[j] + lim) << (j * bits))
    o_ref[...] = word


def _unpack_kernel(w_ref, o_ref, *, k, bits, nlim):
    w = w_ref[...]  # (bm, bn) int32
    mask = (1 << bits) - 1
    for j in range(k):
        o_ref[j, :, :] = ((w >> (j * bits)) & mask) - nlim


@functools.partial(
    jax.jit, static_argnames=("bits", "lim", "block", "interpret")
)
def pack_words_2d(
    x: jax.Array,  # (k, rows, cols) int32 image view
    *,
    bits: int,
    lim: int,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    k, rows, cols = x.shape
    bm, bn = block
    assert k == 32 // bits and rows % bm == 0 and cols % bn == 0, (x.shape, block)
    grid = (rows // bm, cols // bn)
    return pl.pallas_call(
        functools.partial(_pack_kernel, k=k, bits=bits, lim=lim),
        grid=grid,
        in_specs=[pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=interpret,
    )(x)


@functools.partial(
    jax.jit, static_argnames=("bits", "nlim", "block", "interpret")
)
def unpack_words_2d(
    words: jax.Array,  # (rows, cols) int32 transport words
    *,
    bits: int,
    nlim: int,  # accumulated bias n_summed * clip_limit
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    rows, cols = words.shape
    k = 32 // bits
    bm, bn = block
    assert rows % bm == 0 and cols % bn == 0, (words.shape, block)
    grid = (rows // bm, cols // bn)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, k=k, bits=bits, nlim=nlim),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((k, rows, cols), jnp.int32),
        interpret=interpret,
    )(words)
