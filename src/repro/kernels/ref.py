"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's semantics exactly, including the
counter-based PRNG, so tests can assert bit-exact (integer outputs) or
allclose (float outputs) equality across shape/dtype sweeps.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.prng import uniform_from_counter

_INT_LIM = {4: 7, 8: 127, 16: 32767, 32: 2147483647}


def int_compress_ref(
    x: jnp.ndarray,
    alpha: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
) -> jnp.ndarray:
    """Int(α∘x), clipped so the n-worker sum fits `bits`, as int32.

    Counter = flat element index (row-major over the padded 2-D view used by
    the kernel — for the oracle we use the logical flat index, and ops.py
    guarantees the kernel sees the same flat layout).
    """
    orig_shape = x.shape
    xf = x.astype(jnp.float32).reshape(-1)
    scaled = xf * alpha.astype(jnp.float32)
    if stochastic:
        counter = jnp.arange(xf.size, dtype=jnp.uint32)
        u = uniform_from_counter(counter, seed)
        lo = jnp.floor(scaled)
        r = lo + (u < (scaled - lo)).astype(jnp.float32)
    else:
        r = jnp.round(scaled)
    lim = _INT_LIM[bits] // max(n_workers, 1)
    r = jnp.clip(r, -lim, lim)
    return r.astype(jnp.int32).reshape(orig_shape)


def pack_words_ref(
    ints: jnp.ndarray, *, bits: int, n_workers: int
) -> jnp.ndarray:
    """Canonical PackedInt word layout, computed in uint32 mul/add arithmetic
    (deliberately NOT shifts, so the kernels are checked against an
    independent formulation): word[w] = Σ_j (flat[j·m + w] + lim) · 2^(j·b)
    mod 2^32, with m = ceil(size/k), k = 32//bits."""
    k = 32 // bits
    lim = _INT_LIM[bits] // max(n_workers, 1)
    flat = ints.reshape(-1).astype(jnp.int32)
    m = -(-flat.size // k)
    chunks = jnp.pad(flat, (0, k * m - flat.size)).reshape(k, m)
    word = jnp.zeros((m,), jnp.uint32)
    for j in range(k):
        word = word + (chunks[j] + lim).astype(jnp.uint32) * jnp.uint32(
            2 ** (j * bits)
        )
    return word.astype(jnp.int32)


def unpack_words_ref(
    words: jnp.ndarray, shape, *, bits: int, n_summed: int
) -> jnp.ndarray:
    """Inverse of pack_words_ref after an n_summed-worker wrap-around sum:
    field j = (word // 2^(j·b)) mod 2^b − n_summed·lim (uint32 div/mod)."""
    k = 32 // bits
    lim = _INT_LIM[bits] // max(n_summed, 1)
    size = 1
    for s in shape:
        size *= int(s)
    u = words.reshape(-1).astype(jnp.uint32)
    fields = [
        (u // jnp.uint32(2 ** (j * bits)) % jnp.uint32(2**bits)).astype(
            jnp.int32
        )
        - n_summed * lim
        for j in range(k)
    ]
    return jnp.stack(fields).reshape(-1)[:size].reshape(shape)


def fused_unpack_update_ref(
    words: jnp.ndarray,
    param: jnp.ndarray,
    mom: jnp.ndarray,
    *,
    bits: int,
    n_summed: int,
    inv_nalpha: jnp.ndarray,
    lr: jnp.ndarray,
    mu: jnp.ndarray,
    wd: jnp.ndarray,
):
    """unpack_words_ref composed with fused_update_ref."""
    int_sum = unpack_words_ref(
        words, param.shape, bits=bits, n_summed=n_summed
    )
    return fused_update_ref(
        int_sum, param, mom, inv_nalpha=inv_nalpha, lr=lr, mu=mu, wd=wd
    )


def fused_update_ref(
    int_sum: jnp.ndarray,
    param: jnp.ndarray,
    mom: jnp.ndarray,
    *,
    inv_nalpha: jnp.ndarray,
    lr: jnp.ndarray,
    mu: jnp.ndarray,
    wd: jnp.ndarray,
):
    """Dequantize + weight decay + momentum + SGD step (torch semantics)."""
    g = int_sum.astype(jnp.float32) * inv_nalpha + wd * param.astype(jnp.float32)
    new_m = mu * mom.astype(jnp.float32) + g
    new_p = param.astype(jnp.float32) - lr * new_m
    return new_p.astype(param.dtype), new_m.astype(mom.dtype)


def fused_adamw_ref(
    int_sum: jnp.ndarray,
    param: jnp.ndarray,
    mu: jnp.ndarray,
    nu: jnp.ndarray,
    *,
    inv_nalpha,
    lr,
    b1,
    b2,
    eps,
    wd,
    bc1,
    bc2,
    clip=1.0,
    shift: jnp.ndarray | None = None,
):
    """Dequantize (+ global shift) + bias-corrected AdamW step.

    Mirrors the fused kernels' arithmetic: g_agg = shift + Σints/(nα) is
    what the new global shift would be (IntDIANA); the update consumes
    clip·g_agg. Returns (p', mu', nu', g_agg)."""
    g_agg = int_sum.astype(jnp.float32) * inv_nalpha
    if shift is not None:
        g_agg = g_agg + shift.astype(jnp.float32)
    g = clip * g_agg
    p32 = param.astype(jnp.float32)
    new_m = b1 * mu.astype(jnp.float32) + (1.0 - b1) * g
    new_v = b2 * nu.astype(jnp.float32) + (1.0 - b2) * g * g
    step = (new_m / bc1) / (jnp.sqrt(new_v / bc2) + eps)
    new_p = p32 - lr * (step + wd * p32)
    return (
        new_p.astype(param.dtype),
        new_m.astype(mu.dtype),
        new_v.astype(nu.dtype),
        g_agg,
    )


def fused_unpack_adamw_ref(
    words: jnp.ndarray, param: jnp.ndarray, mu: jnp.ndarray, nu: jnp.ndarray,
    *, bits: int, n_summed: int, **kw
):
    """unpack_words_ref composed with fused_adamw_ref."""
    int_sum = unpack_words_ref(
        words, param.shape, bits=bits, n_summed=n_summed
    )
    return fused_adamw_ref(int_sum, param, mu, nu, **kw)


def block_norms_ref(x: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Squared L2 norm of each contiguous row-block of a 2-D array."""
    rows = x.shape[0]
    nblocks = (rows + block_rows - 1) // block_rows
    pad = nblocks * block_rows - rows
    xf = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0)))
    return jnp.sum(
        jnp.square(xf).reshape(nblocks, block_rows, x.shape[1]), axis=(1, 2)
    )
