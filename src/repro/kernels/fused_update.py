"""Pallas kernel: fused dequantize + weight-decay + momentum + SGD step.

Replaces the chain
    g  = Σints * 1/(nα)         (read int, write g)
    g += wd * p                 (read g, p, write g)
    m  = μ m + g                (read m, g, write m)
    p -= lr m                   (read p, m, write p)
— 9 HBM tensor touches — with a single pass: 3 reads (ints, p, m) and
2 writes (p', m'). On a memory-bound elementwise stage this is a ~1.8×
reduction in optimizer-step HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 1024)


def _kernel(sc_ref, ints_ref, p_ref, m_ref, po_ref, mo_ref):
    inv_nalpha = sc_ref[0]
    lr = sc_ref[1]
    mu = sc_ref[2]
    wd = sc_ref[3]
    p = p_ref[...].astype(jnp.float32)
    g = ints_ref[...].astype(jnp.float32) * inv_nalpha + wd * p
    m = mu * m_ref[...].astype(jnp.float32) + g
    po_ref[...] = (p - lr * m).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_update_2d(
    int_sum: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    scalars: jax.Array,  # [inv_nalpha, lr, mu, wd] f32
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    rows, cols = int_sum.shape
    bm, bn = block
    assert rows % bm == 0 and cols % bn == 0
    grid = (rows // bm, cols // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(param.shape, param.dtype),
            jax.ShapeDtypeStruct(mom.shape, mom.dtype),
        ),
        interpret=interpret,
    )(scalars.astype(jnp.float32), int_sum, param, mom)
