"""Pallas kernel: fused dequantize + weight-decay + momentum + SGD step.

Replaces the chain
    g  = Σints * 1/(nα)         (read int, write g)
    g += wd * p                 (read g, p, write g)
    m  = μ m + g                (read m, g, write m)
    p -= lr m                   (read p, m, write p)
— 9 HBM tensor touches — with a single pass: 3 reads (ints, p, m) and
2 writes (p', m'). On a memory-bound elementwise stage this is a ~1.8×
reduction in optimizer-step HBM traffic.

``fused_unpack_update_2d`` is the PackedInt-wire variant: it consumes the
bit-packed int32 transport words straight off the all-reduce (d/k words
instead of d integer lanes read from HBM), unpacking k bias-shifted fields
per word in-register before the identical update arithmetic — so the packed
route never materializes the integer image at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 1024)


def _kernel(sc_ref, ints_ref, p_ref, m_ref, po_ref, mo_ref):
    inv_nalpha = sc_ref[0]
    lr = sc_ref[1]
    mu = sc_ref[2]
    wd = sc_ref[3]
    p = p_ref[...].astype(jnp.float32)
    g = ints_ref[...].astype(jnp.float32) * inv_nalpha + wd * p
    m = mu * m_ref[...].astype(jnp.float32) + g
    po_ref[...] = (p - lr * m).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)


def _unpack_update_kernel(
    sc_ref, w_ref, p_ref, m_ref, po_ref, mo_ref, *, k, bits, nlim
):
    inv_nalpha = sc_ref[0]
    lr = sc_ref[1]
    mu = sc_ref[2]
    wd = sc_ref[3]
    w = w_ref[...]  # (bm, bn) int32 transport words
    mask = (1 << bits) - 1
    for j in range(k):
        s = (((w >> (j * bits)) & mask) - nlim).astype(jnp.float32)
        p = p_ref[j].astype(jnp.float32)
        g = s * inv_nalpha + wd * p
        m = mu * m_ref[j].astype(jnp.float32) + g
        po_ref[j, :, :] = (p - lr * m).astype(po_ref.dtype)
        mo_ref[j, :, :] = m.astype(mo_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "nlim", "block", "interpret")
)
def fused_unpack_update_2d(
    words: jax.Array,  # (rows, cols) int32 packed words
    param: jax.Array,  # (k, rows, cols) image view
    mom: jax.Array,  # (k, rows, cols)
    scalars: jax.Array,  # [inv_nalpha, lr, mu, wd] f32
    *,
    bits: int,
    nlim: int,  # accumulated bias n_summed * clip_limit
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    rows, cols = words.shape
    k = 32 // bits
    bm, bn = block
    assert param.shape == (k, rows, cols) and mom.shape == param.shape
    assert rows % bm == 0 and cols % bn == 0
    grid = (rows // bm, cols // bn)
    wspec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    ispec = pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j))
    return pl.pallas_call(
        functools.partial(_unpack_update_kernel, k=k, bits=bits, nlim=nlim),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), wspec, ispec, ispec],
        out_specs=(ispec, ispec),
        out_shape=(
            jax.ShapeDtypeStruct(param.shape, param.dtype),
            jax.ShapeDtypeStruct(mom.shape, mom.dtype),
        ),
        interpret=interpret,
    )(scalars.astype(jnp.float32), words, param, mom)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_update_2d(
    int_sum: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    scalars: jax.Array,  # [inv_nalpha, lr, mu, wd] f32
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    rows, cols = int_sum.shape
    bm, bn = block
    assert rows % bm == 0 and cols % bn == 0
    grid = (rows // bm, cols // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(param.shape, param.dtype),
            jax.ShapeDtypeStruct(mom.shape, mom.dtype),
        ),
        interpret=interpret,
    )(scalars.astype(jnp.float32), int_sum, param, mom)
