"""Pallas kernels: fused dequantize + optimizer step for the whole family.

The SGD form replaces the chain
    g  = Σints * 1/(nα)         (read int, write g)
    g += wd * p                 (read g, p, write g)
    m  = μ m + g                (read m, g, write m)
    p -= lr m                   (read p, m, write p)
— 9 HBM tensor touches — with a single pass: 3 reads (ints, p, m) and
2 writes (p', m'). On a memory-bound elementwise stage this is a ~1.8×
reduction in optimizer-step HBM traffic. The AdamW form fuses the
bias-corrected moment EMAs the same way (4 reads: ints, p, mu, nu; 3
writes: p', mu', nu' — the moments never leave registers between decode
and apply, vs 13 tensor touches unfused).

``fused_unpack_*_2d`` are the PackedInt-wire variants: they consume the
bit-packed int32 transport words straight off the all-reduce (d/k words
instead of d integer lanes read from HBM), unpacking k bias-shifted fields
per word in-register before the identical update arithmetic — so the packed
route never materializes the integer image at all.

Shift (IntDIANA): with ``has_shift`` every kernel takes one extra f32
tensor h (the replicated global shift) and emits one extra output. The
decoded aggregate becomes g_agg = h + Σints·1/(nα), and the extra output is
g_agg itself — which IS the new global shift (h' = h + mean Q = ĝ), so the
DIANA shift update costs zero extra HBM passes over the decode it fuses
with.

Canonical scalar vectors (f32, one per leaf — inv_nalpha varies per block
under the blockwise α rule; ``clip`` is the global-norm factor
min(1, c/||ĝ||) applied to the aggregate consumed by the update but NOT to
the shift output, matching the unfused route where the clip scales ĝ after
the shift state advanced):

    sgd   : [inv_nalpha, clip, lr, mu, wd]
    adamw : [inv_nalpha, clip, lr, b1, omb1, b2, omb2, eps, wd, bc1, bc2]

(omb1/omb2 = pre-rounded 1-b1 / 1-b2 — see optim.base.FUSED_SCALAR_TAIL for
why they are passed rather than recomputed in-kernel)

(see optim.base.FUSED_SCALAR_TAIL — optim owns the tail order, this module
owns the arithmetic.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 1024)


# ---------------------------------------------------------------------------
# update arithmetic shared by the dense and packed kernels (operates on the
# in-register decoded aggregate; returns the output blocks to write)
# ---------------------------------------------------------------------------
def _apply_sgd(sc, g_agg, p, m):
    clip, lr, mu, wd = sc[1], sc[2], sc[3], sc[4]
    g = clip * g_agg + wd * p
    m_new = mu * m + g
    return p - lr * m_new, m_new


def _apply_adamw(sc, g_agg, p, m, v):
    clip, lr = sc[1], sc[2]
    b1, omb1, b2, omb2 = sc[3], sc[4], sc[5], sc[6]
    eps, wd, bc1, bc2 = sc[7], sc[8], sc[9], sc[10]
    g = clip * g_agg
    m_new = b1 * m + omb1 * g
    v_new = b2 * v + omb2 * g * g
    step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    return p - lr * (step + wd * p), m_new, v_new


# ---------------------------------------------------------------------------
# dense kernels: one lane per coordinate (int8/int16/int32 widening cast)
# ---------------------------------------------------------------------------
def _sgd_kernel(sc_ref, ints_ref, p_ref, m_ref, *refs, has_shift):
    sc = sc_ref
    if has_shift:
        h_ref, po_ref, mo_ref, ho_ref = refs
    else:
        po_ref, mo_ref = refs
    p = p_ref[...].astype(jnp.float32)
    g_agg = ints_ref[...].astype(jnp.float32) * sc[0]
    if has_shift:
        g_agg = g_agg + h_ref[...].astype(jnp.float32)
        ho_ref[...] = g_agg.astype(ho_ref.dtype)
    p_new, m_new = _apply_sgd(sc, g_agg, p, m_ref[...].astype(jnp.float32))
    po_ref[...] = p_new.astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)


def _adamw_kernel(sc_ref, ints_ref, p_ref, m_ref, v_ref, *refs, has_shift):
    sc = sc_ref
    if has_shift:
        h_ref, po_ref, mo_ref, vo_ref, ho_ref = refs
    else:
        po_ref, mo_ref, vo_ref = refs
    p = p_ref[...].astype(jnp.float32)
    g_agg = ints_ref[...].astype(jnp.float32) * sc[0]
    if has_shift:
        g_agg = g_agg + h_ref[...].astype(jnp.float32)
        ho_ref[...] = g_agg.astype(ho_ref.dtype)
    p_new, m_new, v_new = _apply_adamw(
        sc, g_agg, p, m_ref[...].astype(jnp.float32),
        v_ref[...].astype(jnp.float32),
    )
    po_ref[...] = p_new.astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


# ---------------------------------------------------------------------------
# packed kernels: k bias-shifted fields unpacked in-register per int32 word
# ---------------------------------------------------------------------------
def _unpack_sgd_kernel(sc_ref, w_ref, p_ref, m_ref, *refs,
                       k, bits, nlim, has_shift):
    sc = sc_ref
    if has_shift:
        h_ref, po_ref, mo_ref, ho_ref = refs
    else:
        po_ref, mo_ref = refs
    w = w_ref[...]  # (bm, bn) int32 transport words
    mask = (1 << bits) - 1
    for j in range(k):
        s = (((w >> (j * bits)) & mask) - nlim).astype(jnp.float32)
        g_agg = s * sc[0]
        if has_shift:
            g_agg = g_agg + h_ref[j].astype(jnp.float32)
            ho_ref[j, :, :] = g_agg.astype(ho_ref.dtype)
        p_new, m_new = _apply_sgd(
            sc, g_agg, p_ref[j].astype(jnp.float32),
            m_ref[j].astype(jnp.float32),
        )
        po_ref[j, :, :] = p_new.astype(po_ref.dtype)
        mo_ref[j, :, :] = m_new.astype(mo_ref.dtype)


def _unpack_adamw_kernel(sc_ref, w_ref, p_ref, m_ref, v_ref, *refs,
                         k, bits, nlim, has_shift):
    sc = sc_ref
    if has_shift:
        h_ref, po_ref, mo_ref, vo_ref, ho_ref = refs
    else:
        po_ref, mo_ref, vo_ref = refs
    w = w_ref[...]
    mask = (1 << bits) - 1
    for j in range(k):
        s = (((w >> (j * bits)) & mask) - nlim).astype(jnp.float32)
        g_agg = s * sc[0]
        if has_shift:
            g_agg = g_agg + h_ref[j].astype(jnp.float32)
            ho_ref[j, :, :] = g_agg.astype(ho_ref.dtype)
        p_new, m_new, v_new = _apply_adamw(
            sc, g_agg, p_ref[j].astype(jnp.float32),
            m_ref[j].astype(jnp.float32), v_ref[j].astype(jnp.float32),
        )
        po_ref[j, :, :] = p_new.astype(po_ref.dtype)
        mo_ref[j, :, :] = m_new.astype(mo_ref.dtype)
        vo_ref[j, :, :] = v_new.astype(vo_ref.dtype)


_DENSE_KERNELS = {"sgd": (_sgd_kernel, 1), "adamw": (_adamw_kernel, 2)}
_PACKED_KERNELS = {"sgd": (_unpack_sgd_kernel, 1),
                   "adamw": (_unpack_adamw_kernel, 2)}


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("kernel", "block", "interpret")
)
def fused_apply_2d(
    int_sum: jax.Array,  # (rows, cols) integer lanes (any int dtype)
    param: jax.Array,  # (rows, cols)
    opt: tuple,  # per-kernel f32 state tensors, each (rows, cols)
    scalars: jax.Array,  # canonical scalar vector (see module docstring)
    shift: jax.Array | None = None,  # (rows, cols) f32 global shift
    *,
    kernel: str = "sgd",
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Dense fused route: (p', opt', shift'|None) in one HBM pass."""
    body, n_state = _DENSE_KERNELS[kernel]
    assert len(opt) == n_state, (kernel, len(opt))
    rows, cols = int_sum.shape
    bm, bn = block
    assert rows % bm == 0 and cols % bn == 0
    grid = (rows // bm, cols // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    has_shift = shift is not None
    inputs = [scalars.astype(jnp.float32), int_sum, param, *opt]
    out_shape = [jax.ShapeDtypeStruct(param.shape, param.dtype)]
    out_shape += [jax.ShapeDtypeStruct(o.shape, o.dtype) for o in opt]
    if has_shift:
        inputs.append(shift)
        out_shape.append(jax.ShapeDtypeStruct(shift.shape, shift.dtype))
    outs = pl.pallas_call(
        functools.partial(body, has_shift=has_shift),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]
        + [spec] * (len(inputs) - 1),
        out_specs=tuple([spec] * len(out_shape)),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*inputs)
    new_p, new_opt = outs[0], tuple(outs[1 : 1 + n_state])
    return new_p, new_opt, (outs[-1] if has_shift else None)


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "bits", "nlim", "block", "interpret"),
)
def fused_unpack_apply_2d(
    words: jax.Array,  # (rows, cols) int32 packed words
    param: jax.Array,  # (k, rows, cols) image view
    opt: tuple,  # per-kernel f32 state tensors, each (k, rows, cols)
    scalars: jax.Array,
    shift: jax.Array | None = None,  # (k, rows, cols) f32 global shift
    *,
    kernel: str = "sgd",
    bits: int = 8,
    nlim: int = 0,  # accumulated bias n_summed * clip_limit
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Packed fused route: unpack in-register + update, one HBM pass."""
    body, n_state = _PACKED_KERNELS[kernel]
    assert len(opt) == n_state, (kernel, len(opt))
    rows, cols = words.shape
    k = 32 // bits
    bm, bn = block
    assert param.shape == (k, rows, cols)
    assert all(o.shape == param.shape for o in opt)
    assert rows % bm == 0 and cols % bn == 0
    grid = (rows // bm, cols // bn)
    wspec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    ispec = pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j))
    has_shift = shift is not None
    inputs = [scalars.astype(jnp.float32), words, param, *opt]
    in_specs = [pl.BlockSpec(memory_space=pl.ANY), wspec]
    in_specs += [ispec] * (1 + len(opt))
    out_shape = [jax.ShapeDtypeStruct(param.shape, param.dtype)]
    out_shape += [jax.ShapeDtypeStruct(o.shape, o.dtype) for o in opt]
    if has_shift:
        inputs.append(shift)
        in_specs.append(ispec)
        out_shape.append(jax.ShapeDtypeStruct(shift.shape, shift.dtype))
    outs = pl.pallas_call(
        functools.partial(body, k=k, bits=bits, nlim=nlim,
                          has_shift=has_shift),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple([ispec] * len(out_shape)),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*inputs)
    new_p, new_opt = outs[0], tuple(outs[1 : 1 + n_state])
    return new_p, new_opt, (outs[-1] if has_shift else None)


# ---------------------------------------------------------------------------
# named per-kernel entry points
# ---------------------------------------------------------------------------
def fused_adamw_2d(int_sum, param, mu, nu, scalars, shift=None, *,
                   block=DEFAULT_BLOCK, interpret=False):
    """Dense decode → bias-corrected moment update → AdamW step, one pass."""
    p, (m, v), h = fused_apply_2d(
        int_sum, param, (mu, nu), scalars, shift,
        kernel="adamw", block=block, interpret=interpret,
    )
    return p, m, v, h


def fused_unpack_adamw_2d(words, param, mu, nu, scalars, shift=None, *,
                          bits, nlim, block=DEFAULT_BLOCK, interpret=False):
    """PackedInt decode → bias-corrected moment update → AdamW step: packed
    words unpacked in-register, moments never leave registers between decode
    and apply."""
    p, (m, v), h = fused_unpack_apply_2d(
        words, param, (mu, nu), scalars, shift,
        kernel="adamw", bits=bits, nlim=nlim, block=block,
        interpret=interpret,
    )
    return p, m, v, h


# ---------------------------------------------------------------------------
# legacy single-kernel entry points (SGD, no shift) — kept for the oracle
# tests and micro-benchmarks; scalar layout [inv_nalpha, lr, mu, wd]
# ---------------------------------------------------------------------------
def _legacy_scalars(scalars):
    """[inv_nalpha, lr, mu, wd] -> [inv_nalpha, clip=1, lr, mu, wd]."""
    s = scalars.astype(jnp.float32)
    return jnp.stack([s[0], jnp.float32(1.0), s[1], s[2], s[3]])


@functools.partial(
    jax.jit, static_argnames=("bits", "nlim", "block", "interpret")
)
def fused_unpack_update_2d(
    words: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    scalars: jax.Array,  # [inv_nalpha, lr, mu, wd] f32
    *,
    bits: int,
    nlim: int,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    p, (m,), _ = fused_unpack_apply_2d(
        words, param, (mom,), _legacy_scalars(scalars), None,
        kernel="sgd", bits=bits, nlim=nlim, block=block, interpret=interpret,
    )
    return p, m


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_update_2d(
    int_sum: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    scalars: jax.Array,  # [inv_nalpha, lr, mu, wd] f32
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
):
    p, (m,), _ = fused_apply_2d(
        int_sum, param, (mom,), _legacy_scalars(scalars), None,
        kernel="sgd", block=block, interpret=interpret,
    )
    return p, m
