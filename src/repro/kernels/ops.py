"""Public jit'd wrappers around the Pallas kernels.

Handles arbitrary tensor shapes by flattening to a padded row-major 2-D view
(pad-at-end keeps the kernel's flat element counter identical to the
oracle's logical index, so stochastic rounding is bit-exact vs ref.py).

On non-TPU backends the kernels run under ``interpret=True`` (the kernel body
executed op-by-op on CPU) — the TARGET remains TPU Mosaic; CPU execution is
for validation only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_norms as _bn
from repro.kernels import fused_update as _fu
from repro.kernels import int_compress as _ic
from repro.kernels import wire_pack as _wp


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


_SMALL = 2**18


def _block_for(size: int):
    return (8, 128) if size < _SMALL else _ic.DEFAULT_BLOCK


def _to_2d(flat: jax.Array, block):
    bm, bn = block
    chunk = bm * bn
    padded = (flat.size + chunk - 1) // chunk * chunk
    flat = jnp.pad(flat, (0, padded - flat.size))
    return flat.reshape(padded // bn, bn)


def seed_from_key(key: jax.Array) -> jax.Array:
    return jax.random.bits(key, (), jnp.uint32).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_workers", "bits", "stochastic", "interpret")
)
def int_compress(
    x: jax.Array,
    alpha: jax.Array,
    key: jax.Array,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Int(α∘x) clipped for the n-worker sum — kernel-accelerated encode."""
    interpret = _interpret_default() if interpret is None else interpret
    seed = seed_from_key(key)
    shape = x.shape
    block = _block_for(x.size)
    x2 = _to_2d(x.reshape(-1).astype(jnp.float32), block)
    out = _ic.int_compress_2d(
        x2,
        alpha,
        seed,
        n_workers=n_workers,
        bits=bits,
        stochastic=stochastic,
        block=block,
        interpret=interpret,
    )
    return out.reshape(-1)[: x.size].reshape(shape)


def _image_view(flat: jax.Array, k: int, m: int, block):
    """(k·m,) chunk-major flat image -> (k, rows, bn) view aligned to the
    word-block grid (words padded along the word axis only, so the canonical
    word layout word[w] <- flat[j·m + w] is preserved)."""
    bm, bn = block
    chunk = bm * bn
    mp = (m + chunk - 1) // chunk * chunk
    ch = jnp.pad(flat.reshape(k, m), ((0, 0), (0, mp - m)))
    return ch.reshape(k, mp // bn, bn)


@functools.partial(
    jax.jit, static_argnames=("bits", "n_workers", "interpret")
)
def pack_words(
    ints: jax.Array,
    *,
    bits: int,
    n_workers: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Bit-pack a clipped integer image into int32 transport words (flat,
    length ceil(size / (32//bits))) — kernel-accelerated PackedInt.pack."""
    interpret = _interpret_default() if interpret is None else interpret
    k = 32 // bits
    lim = _ic.clip_limit(bits, n_workers)
    flat = ints.reshape(-1).astype(jnp.int32)
    m = -(-flat.size // k)
    flat = jnp.pad(flat, (0, k * m - flat.size))
    block = _block_for(m)
    x3 = _image_view(flat, k, m, block)
    w2 = _wp.pack_words_2d(
        x3, bits=bits, lim=lim, block=block, interpret=interpret
    )
    return w2.reshape(-1)[:m]


@functools.partial(
    jax.jit, static_argnames=("shape", "bits", "n_summed", "interpret")
)
def unpack_words(
    words: jax.Array,
    shape,
    *,
    bits: int,
    n_summed: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Summed transport words -> summed integer image of `shape` (int32)."""
    interpret = _interpret_default() if interpret is None else interpret
    k = 32 // bits
    nlim = n_summed * _ic.clip_limit(bits, n_summed)
    size = 1
    for s in shape:
        size *= int(s)
    m = words.size
    assert m == -(-size // k), (m, size, k)
    block = _block_for(m)
    w2 = _to_2d(words.reshape(-1), block)
    out3 = _wp.unpack_words_2d(
        w2, bits=bits, nlim=nlim, block=block, interpret=interpret
    )
    flat = out3.reshape(k, -1)[:, :m].reshape(-1)[:size]
    return flat.reshape(shape)


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "bits", "n_summed", "interpret"),
)
def fused_unpack_apply(
    words: jax.Array,
    param: jax.Array,
    opt: tuple,  # per-kernel f32 state tensors, param-shaped
    scalars: jax.Array,  # canonical vector (see kernels/fused_update.py)
    shift: jax.Array | None = None,
    *,
    kernel: str = "sgd",
    bits: int,
    n_summed: int,
    interpret: bool | None = None,
):
    """PackedInt fused route, any optimizer kernel: the update consumes the
    bit-packed transport words directly (no unpacked integer image ever hits
    HBM). Returns (param', opt', shift'|None)."""
    interpret = _interpret_default() if interpret is None else interpret
    k = 32 // bits
    nlim = n_summed * _ic.clip_limit(bits, n_summed)
    shape, d = param.shape, param.size
    m = words.size
    assert m == -(-d // k), (m, d, k)
    block = _block_for(m)
    w2 = _to_2d(words.reshape(-1), block)

    def view(t):
        flat = t.reshape(-1).astype(jnp.float32)
        return _image_view(jnp.pad(flat, (0, k * m - d)), k, m, block)

    po3, opt3, ho3 = _fu.fused_unpack_apply_2d(
        w2, view(param), tuple(view(o) for o in opt), scalars,
        None if shift is None else view(shift),
        kernel=kernel, bits=bits, nlim=nlim, block=block,
        interpret=interpret,
    )

    def unview(t, dt):
        flat = t.reshape(k, -1)[:, :m].reshape(-1)[:d]
        return flat.reshape(shape).astype(dt)

    return (
        unview(po3, param.dtype),
        tuple(unview(o3, o.dtype) for o3, o in zip(opt3, opt)),
        None if ho3 is None else unview(ho3, shift.dtype),
    )


@functools.partial(jax.jit, static_argnames=("kernel", "interpret"))
def fused_apply(
    int_sum: jax.Array,
    param: jax.Array,
    opt: tuple,
    scalars: jax.Array,
    shift: jax.Array | None = None,
    *,
    kernel: str = "sgd",
    interpret: bool | None = None,
):
    """Dense fused route, any optimizer kernel: optimizer step fused with
    integer dequantization. Returns (param', opt', shift'|None)."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = param.shape
    block = _block_for(param.size)
    to2 = lambda t: _to_2d(t.reshape(-1).astype(jnp.float32), block)
    po, opt2, ho = _fu.fused_apply_2d(
        _to_2d(int_sum.reshape(-1), block), to2(param),
        tuple(to2(o) for o in opt), scalars,
        None if shift is None else to2(shift),
        kernel=kernel, block=block, interpret=interpret,
    )
    unpad = lambda a, dt: a.reshape(-1)[: param.size].reshape(shape).astype(dt)
    return (
        unpad(po, param.dtype),
        tuple(unpad(o2, o.dtype) for o2, o in zip(opt2, opt)),
        None if ho is None else unpad(ho, shift.dtype),
    )


def _sgd_scalars(inv_nalpha, lr, mu, wd):
    return jnp.stack(
        [
            jnp.asarray(inv_nalpha, jnp.float32),
            jnp.float32(1.0),  # clip
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(wd, jnp.float32),
        ]
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "n_summed", "interpret")
)
def fused_unpack_update(
    words: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    inv_nalpha: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
    wd: jax.Array,
    *,
    bits: int,
    n_summed: int,
    interpret: bool | None = None,
):
    """Momentum-SGD shorthand over :func:`fused_unpack_apply`."""
    p, (m,), _ = fused_unpack_apply(
        words, param, (mom,), _sgd_scalars(inv_nalpha, lr, mu, wd),
        kernel="sgd", bits=bits, n_summed=n_summed, interpret=interpret,
    )
    return p, m


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_update(
    int_sum: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    inv_nalpha: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
    wd: jax.Array,
    *,
    interpret: bool | None = None,
):
    """p', m' = sgd-with-momentum step fused with integer dequantization."""
    p, (m,), _ = fused_apply(
        int_sum, param, (mom,), _sgd_scalars(inv_nalpha, lr, mu, wd),
        kernel="sgd", interpret=interpret,
    )
    return p, m


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq_norm(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """||x||² via the block-norms reduction kernel (single block)."""
    interpret = _interpret_default() if interpret is None else interpret
    block = _block_for(x.size)
    x2 = _to_2d(x.reshape(-1).astype(jnp.float32), block)
    out = _bn.block_norms_2d(
        x2, block_rows=x2.shape[0], tile=(block[0], x2.shape[1]), interpret=interpret
    )
    return out[0]


@functools.partial(jax.jit, static_argnames=("nblocks", "interpret"))
def block_sq_norms(x: jax.Array, nblocks: int, *, interpret: bool | None = None):
    """Squared norms of `nblocks` equal contiguous chunks of flat(x)."""
    interpret = _interpret_default() if interpret is None else interpret
    flat = x.reshape(-1).astype(jnp.float32)
    bm, bn = (8, 128)
    per = (flat.size + nblocks - 1) // nblocks
    per = (per + bm * bn - 1) // (bm * bn) * (bm * bn)
    flat = jnp.pad(flat, (0, per * nblocks - flat.size))
    x2 = flat.reshape(per * nblocks // bn, bn)
    return _bn.block_norms_2d(
        x2, block_rows=per // bn, tile=(bm, bn), interpret=interpret
    )
