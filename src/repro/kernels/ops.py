"""Public jit'd wrappers around the Pallas kernels.

Handles arbitrary tensor shapes by flattening to a padded row-major 2-D view
(pad-at-end keeps the kernel's flat element counter identical to the
oracle's logical index, so stochastic rounding is bit-exact vs ref.py).

On non-TPU backends the kernels run under ``interpret=True`` (the kernel body
executed op-by-op on CPU) — the TARGET remains TPU Mosaic; CPU execution is
for validation only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_norms as _bn
from repro.kernels import fused_update as _fu
from repro.kernels import int_compress as _ic


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


_SMALL = 2**18


def _block_for(size: int):
    return (8, 128) if size < _SMALL else _ic.DEFAULT_BLOCK


def _to_2d(flat: jax.Array, block):
    bm, bn = block
    chunk = bm * bn
    padded = (flat.size + chunk - 1) // chunk * chunk
    flat = jnp.pad(flat, (0, padded - flat.size))
    return flat.reshape(padded // bn, bn)


def seed_from_key(key: jax.Array) -> jax.Array:
    return jax.random.bits(key, (), jnp.uint32).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_workers", "bits", "stochastic", "interpret")
)
def int_compress(
    x: jax.Array,
    alpha: jax.Array,
    key: jax.Array,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Int(α∘x) clipped for the n-worker sum — kernel-accelerated encode."""
    interpret = _interpret_default() if interpret is None else interpret
    seed = seed_from_key(key)
    shape = x.shape
    block = _block_for(x.size)
    x2 = _to_2d(x.reshape(-1).astype(jnp.float32), block)
    out = _ic.int_compress_2d(
        x2,
        alpha,
        seed,
        n_workers=n_workers,
        bits=bits,
        stochastic=stochastic,
        block=block,
        interpret=interpret,
    )
    return out.reshape(-1)[: x.size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_update(
    int_sum: jax.Array,
    param: jax.Array,
    mom: jax.Array,
    inv_nalpha: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
    wd: jax.Array,
    *,
    interpret: bool | None = None,
):
    """p', m' = sgd-with-momentum step fused with integer dequantization."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = param.shape
    block = _block_for(param.size)
    ints2 = _to_2d(int_sum.reshape(-1), block)
    p2 = _to_2d(param.reshape(-1).astype(jnp.float32), block)
    m2 = _to_2d(mom.reshape(-1).astype(jnp.float32), block)
    scalars = jnp.stack(
        [
            jnp.asarray(inv_nalpha, jnp.float32),
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(wd, jnp.float32),
        ]
    )
    po, mo = _fu.fused_update_2d(ints2, p2, m2, scalars, block=block, interpret=interpret)
    unpad = lambda a, dt: a.reshape(-1)[: param.size].reshape(shape).astype(dt)
    return unpad(po, param.dtype), unpad(mo, mom.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq_norm(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """||x||² via the block-norms reduction kernel (single block)."""
    interpret = _interpret_default() if interpret is None else interpret
    block = _block_for(x.size)
    x2 = _to_2d(x.reshape(-1).astype(jnp.float32), block)
    out = _bn.block_norms_2d(
        x2, block_rows=x2.shape[0], tile=(block[0], x2.shape[1]), interpret=interpret
    )
    return out[0]


@functools.partial(jax.jit, static_argnames=("nblocks", "interpret"))
def block_sq_norms(x: jax.Array, nblocks: int, *, interpret: bool | None = None):
    """Squared norms of `nblocks` equal contiguous chunks of flat(x)."""
    interpret = _interpret_default() if interpret is None else interpret
    flat = x.reshape(-1).astype(jnp.float32)
    bm, bn = (8, 128)
    per = (flat.size + nblocks - 1) // nblocks
    per = (per + bm * bn - 1) // (bm * bn) * (bm * bn)
    flat = jnp.pad(flat, (0, per * nblocks - flat.size))
    x2 = flat.reshape(per * nblocks // bn, bn)
    return _bn.block_norms_2d(
        x2, block_rows=per // bn, tile=(bm, bn), interpret=interpret
    )
