"""Pallas kernel: per-row-block squared L2 norms (for blockwise α, Alg. 2).

Grid iterates over (block, tile-within-block); the f32 accumulator for each
block lives in the output VMEM block across the inner grid dimension
(TPU grid execution is sequential, so read-modify-write accumulation across
grid steps on the same output block is well-defined)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (256, 1024)


def _kernel(x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("block_rows", "tile", "interpret"))
def block_norms_2d(
    x: jax.Array,
    *,
    block_rows: int,
    tile=DEFAULT_TILE,
    interpret: bool = False,
) -> jax.Array:
    """x: (rows, cols); rows % block_rows == 0; returns (rows//block_rows,)
    squared norms. block_rows % tile[0] == 0 and cols % tile[1] == 0."""
    rows, cols = x.shape
    bm, bn = tile
    assert rows % block_rows == 0 and block_rows % bm == 0 and cols % bn == 0
    nblocks = rows // block_rows
    tiles_per_block = (block_rows // bm) * (cols // bn)
    tb_rows = block_rows // bm

    def x_map(b, j):
        # j enumerates tiles inside block b, row-major
        return (b * tb_rows + j // (cols // bn), j % (cols // bn))

    out = pl.pallas_call(
        _kernel,
        grid=(nblocks, tiles_per_block),
        in_specs=[pl.BlockSpec((bm, bn), x_map)],
        out_specs=pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, 0]
