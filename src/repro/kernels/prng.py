"""Counter-based PRNG shared by kernels and their oracles.

fmix32 (MurmurHash3 finalizer) over (element-counter ^ seed): statistically
solid for rounding noise, stateless, and expressible in pure jnp uint32 ops —
so the Pallas kernel and the ref.py oracle produce *identical* bits, enabling
bit-exact validation of the stochastic rounding path on CPU.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy scalars embed as literals inside Pallas kernels (jnp arrays would be
# captured constants, which pallas_call rejects)
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """MurmurHash3 32-bit finalizer; input/output uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def uniform_from_counter(counter: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """U[0,1) floats from an integer counter grid and an int32 seed."""
    h = fmix32(counter.astype(jnp.uint32) * _GOLDEN + seed.astype(jnp.uint32))
    # 24 high-quality mantissa bits -> [0, 1)
    return (h >> np.uint32(8)).astype(jnp.float32) * np.float32(2.0**-24)
