"""Pallas kernel: fused scale + stochastic/deterministic round + clip → int32.

One HBM read of the f32 gradient tile, one HBM write of the int32 image —
the entire Int(α∘g) operator of the paper in a single VMEM pass.

Tiling: 2-D grid over a (rows, cols) view; blocks are (BM, BN) with BN a
multiple of 128 (lane width) and BM a multiple of 8 (sublane, f32). VMEM
footprint per step: BM*BN*4B (in) + BM*BN*4B (out) = 2 MiB at the default
(256, 1024), comfortably inside the ~16 MiB VMEM budget while long enough to
amortize HBM latency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.prng import uniform_from_counter

_INT_LIM = {4: 7, 8: 127, 16: 32767, 32: 2147483647}


def clip_limit(bits: int, n_workers: int) -> int:
    """§5.1 clip limit as the kernels see it (single kernel-layer copy;
    the wire layer raises its typed WireRangeError before reaching here)."""
    lim = _INT_LIM[bits] // max(n_workers, 1)
    if lim == 0:
        raise ValueError(
            f"int{bits} wire cannot carry a sum over {n_workers} workers "
            "(clip limit degenerates to 0; widen the wire)"
        )
    return lim


DEFAULT_BLOCK = (256, 1024)


def _kernel(alpha_ref, seed_ref, x_ref, o_ref, *, lim, stochastic, ncols, block):
    bm, bn = block
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    scaled = x * alpha_ref[0]
    if stochastic:
        # global flat element counter (row-major over the padded 2-D view):
        # identical to the oracle's jnp.arange counter.
        row = lax.broadcasted_iota(jnp.uint32, (bm, bn), 0) + jnp.uint32(i * bm)
        col = lax.broadcasted_iota(jnp.uint32, (bm, bn), 1) + jnp.uint32(j * bn)
        counter = row * jnp.uint32(ncols) + col
        u = uniform_from_counter(counter, seed_ref[0])
        lo = jnp.floor(scaled)
        r = lo + (u < (scaled - lo)).astype(jnp.float32)
    else:
        r = jnp.round(scaled)
    r = jnp.clip(r, -float(lim), float(lim))
    o_ref[...] = r.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_workers", "bits", "stochastic", "block", "interpret"),
)
def int_compress_2d(
    x: jax.Array,
    alpha: jax.Array,
    seed: jax.Array,
    *,
    n_workers: int,
    bits: int = 32,
    stochastic: bool = True,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """x: (rows, cols) f32, rows % block[0] == 0, cols % block[1] == 0."""
    rows, cols = x.shape
    bm, bn = block
    assert rows % bm == 0 and cols % bn == 0, (x.shape, block)
    lim = clip_limit(bits, n_workers)
    grid = (rows // bm, cols // bn)
    return pl.pallas_call(
        functools.partial(
            _kernel, lim=lim, stochastic=stochastic, ncols=cols, block=block
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # alpha (scalar, whole array)
            pl.BlockSpec(memory_space=pl.ANY),  # seed
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=interpret,
    )(
        alpha.reshape(1).astype(jnp.float32),
        seed.reshape(1).astype(jnp.int32),
        x,
    )
