"""seamless-m4t-medium [audio]: 12L enc + 12L dec d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 — enc-dec; audio frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    enc_layers=12,
    dec_layers=12,
    frontend="audio",
    frontend_dim=160,
    source="arXiv:2308.11596",
)
