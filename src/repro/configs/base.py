"""Architecture + shape registry.

Each assigned architecture lives in its own module (one file per arch, per
the deliverable structure) and registers an exact ``ModelConfig``. Shapes are
shared by all LM-family archs. ``smoke_config`` derives a reduced same-family
config for CPU tests; full configs are only ever lowered via the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window attention
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # MLA
    kv_lora: int = 0
    # hybrid / ssm
    ssm_state: int = 0
    attn_every: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub
    frontend: Optional[str] = None  # vit | audio
    frontend_dim: int = 0
    n_frontend_tokens: int = 0
    tie_embeddings: bool = False
    # remat policy for the layer scan: "full" (recompute everything) or
    # "save_psum" (save TP collective outputs — trades activation memory for
    # a third of the TP all-reduce traffic; see EXPERIMENTS.md §Perf)
    remat_policy: str = "full"
    # provenance
    source: str = ""

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / SWA families)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all ten assigned archs have a decode path


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_ARCH_MODULES = [
    "qwen2_5_32b",
    "granite_8b",
    "minitron_4b",
    "h2o_danube_3_4b",
    "zamba2_2_7b",
    "internvl2_2b",
    "deepseek_v2_lite_16b",
    "mixtral_8x22b",
    "xlstm_125m",
    "seamless_m4t_medium",
]

ARCHS: dict[str, ModelConfig] = {}


def _load():
    if ARCHS:
        return
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        cfg = mod.CONFIG
        ARCHS[cfg.name] = cfg


def get_arch(name: str) -> ModelConfig:
    _load()
    if name not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; options {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise ValueError(f"unknown shape {name!r}; options {sorted(SHAPES)}")
    return SHAPES[name]


def runnable_cells() -> list[tuple[str, str, bool]]:
    """All 40 (arch, shape) cells with a runnable flag.
    long_500k is skipped for pure full-attention archs (see DESIGN.md)."""
    _load()
    out = []
    for a, cfg in ARCHS.items():
        for s in SHAPES:
            runnable = True
            if s == "long_500k" and not cfg.subquadratic:
                runnable = False
            out.append((a, s, runnable))
    return out


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        kv_lora=32 if cfg.kv_lora else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=16 if cfg.ssm_state else 0,
        attn_every=2 if cfg.attn_every else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        dec_layers=2 if cfg.dec_layers else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        window=64 if cfg.window else None,
    )
    if cfg.family == "hybrid":
        kw["n_layers"] = 4  # 2 blocks x attn_every=2
    if cfg.family == "ssm":
        kw["n_layers"] = 3  # one (m,m,s) block
        kw["head_dim"] = 16
    return dataclasses.replace(cfg, **kw)
