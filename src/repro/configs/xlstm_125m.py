"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks in (m,m,s) pattern; recurrent, sub-quadratic. [arXiv:2405.04517;
unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
