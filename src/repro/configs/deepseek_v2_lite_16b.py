"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, 64 routed experts top-6 + 2 shared experts.
[arXiv:2405.04434; hf] (header config: 64e top-6)"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    kv_lora=512,
    source="arXiv:2405.04434",
)
