from repro.configs.base import (
    ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_arch,
    get_shape,
    runnable_cells,
    smoke_config,
)
