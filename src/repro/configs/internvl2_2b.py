"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend (STUB: input_specs provides precomputed
patch embeddings) + InternLM2 decoder. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    frontend="vit",
    frontend_dim=1024,
    n_frontend_tokens=256,
    source="arXiv:2404.16821",
)
