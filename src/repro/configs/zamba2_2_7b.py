"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block applied
every 9 layers with concat[h, embed] input. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    attn_every=9,
    source="arXiv:2411.15242",
)
