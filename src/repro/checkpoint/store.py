"""Fault-tolerant checkpointing.

Production properties implemented here:
  * atomic publish — write to ``step_N.tmp/`` then os.rename (a crashed
    writer never corrupts the latest checkpoint);
  * keep-last-k garbage collection;
  * async background writer (training never blocks on disk);
  * restore-with-remesh: arrays are saved in host (global) layout, so a
    restart may use a different mesh / worker count — required by elastic
    scaling (runtime/elastic.py);
  * integrity: a manifest with per-array shapes/dtypes + a checksum of the
    tree structure, verified on load.

On a real multi-host pod each process would write its addressable shards
(à la orbax); on this single-process container the host layout is the global
layout, which keeps the semantics identical.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, jax.tree.structure(tree)


class CheckpointStore:
    def __init__(self, directory: str, keep_last: int = 3, async_writes: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_writes
        self._err: Optional[BaseException] = None
        if async_writes:
            self._thread = threading.Thread(target=self._writer_loop, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot to host memory immediately; write in background."""
        arrays, _ = _flatten(tree)
        if self._async:
            self._q.put((step, arrays, extra or {}))
        else:
            self._write(step, arrays, extra or {})

    def wait(self):
        """Block until all queued writes are on disk (tests / shutdown)."""
        if self._async:
            self._q.join()
        if self._err:
            raise self._err

    def _writer_loop(self):
        while True:
            step, arrays, extra = self._q.get()
            try:
                self._write(step, arrays, extra)
            except BaseException as e:  # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, arrays: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        h = hashlib.sha256()
        for key in sorted(arrays):
            a = arrays[key]
            fn = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(os.path.join(tmp, fn), a)
            manifest["arrays"][key] = {
                "file": fn,
                "shape": list(a.shape),
                "dtype": str(a.dtype),
            }
            h.update(key.encode())
            h.update(str(a.shape).encode())
        manifest["tree_checksum"] = h.hexdigest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None, shardings=None):
        """Load into the structure of `tree_like`; with `shardings`, arrays
        are device_put with the (possibly different) target mesh — this is
        the re-mesh path used after elastic scale-down."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        for key, meta in manifest["arrays"].items():
            arrays[key] = np.load(os.path.join(d, meta["file"]))
        want, _ = _flatten(tree_like)
        if sorted(want) != sorted(arrays):
            missing = set(want) - set(arrays)
            extra = set(arrays) - set(want)
            raise ValueError(f"tree mismatch: missing={missing} extra={extra}")

        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        out = []
        for path, leaf in leaves:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            a = arrays[key].astype(leaf.dtype)
            if a.shape != leaf.shape:
                raise ValueError(f"{key}: shape {a.shape} != expected {leaf.shape}")
            out.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest["extra"], step
