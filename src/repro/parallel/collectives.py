"""Version-portable collectives layer — THE distributed execution surface.

Every shard_map entry point, mesh helper and raw collective the codebase
uses routes through this module, so JAX API drift is absorbed in exactly one
place. Two generations of the API are supported by feature detection (never
by version-string comparison):

  * new-style ``jax.shard_map``         (replication check kwarg: check_vma)
  * ``jax.experimental.shard_map``      (replication check kwarg: check_rep)

Contract (relied on by launch/step.py, the tests and future backends):

  * :func:`shard_map` — keyword-only (mesh, in_specs, out_specs, check_vma);
    ``check_vma`` is translated to whatever the installed JAX calls its
    replication/varying-manual-axes check.
  * :func:`sharded_jit` — the step-builder pipeline terminal: shard_map the
    body, jit it with NamedShardings derived from the same specs, optionally
    donate buffers. All step builders terminate here.
  * axis primitives (:func:`psum_tree`, :func:`pmax_tree`, ...) work both
    inside shard_map over a real mesh AND inside ``vmap(axis_name=...)`` —
    which is what lets the n-worker simulation (core/simulate.py) execute
    the identical algorithm on one device.
"""
from __future__ import annotations

import inspect
from functools import partial
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# shard_map resolution (the ONE place in src/ that touches the raw API)
# ---------------------------------------------------------------------------
def _resolve_shard_map() -> Tuple[Callable, str | None]:
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental import shard_map as _esm

        fn = _esm.shard_map
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic wrappers
        params = {}
    for name in ("check_vma", "check_rep"):
        if name in params:
            return fn, name
    return fn, None


_SHARD_MAP, _CHECK_KWARG = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Portable shard_map: maps ``check_vma`` onto the installed API."""
    kwargs = {_CHECK_KWARG: check_vma} if _CHECK_KWARG else {}
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# ---------------------------------------------------------------------------
# typed mesh helpers
# ---------------------------------------------------------------------------
def mesh_from_counts(*, data: int = 1, model: int = 1, pod: int | None = None):
    """Build the production-shaped mesh from axis sizes."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """The data-parallel (gradient-sync) axes: everything except `model`."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_sizes_of(mesh) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in dp_axes_of(mesh))


def axis_spec(axes: Sequence[str]):
    """PartitionSpec entry for one array dim sharded over `axes`."""
    axes = tuple(axes)
    return axes if len(axes) > 1 else axes[0]


def named_shardings(mesh, tree_specs):
    """PartitionSpec tree -> NamedSharding tree over `mesh`."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def sharded_jit(
    body,
    mesh,
    in_specs,
    out_specs,
    *,
    donate: Tuple[int, ...] = (),
    shard_outputs: bool = True,
    check_vma: bool = False,
):
    """The unified step-builder pipeline terminal: shard_map + jit.

    Returns the jitted function; in/out NamedShardings are derivable from the
    same specs via :func:`named_shardings` (step builders record them on
    their StepArtifacts).
    """
    sm = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=check_vma,
    )
    return jax.jit(
        sm,
        in_shardings=named_shardings(mesh, in_specs),
        out_shardings=named_shardings(mesh, out_specs) if shard_outputs else None,
        donate_argnums=donate,
    )


# ---------------------------------------------------------------------------
# axis primitives (work under shard_map AND vmap(axis_name=...))
# ---------------------------------------------------------------------------
def axis_index(axis: str):
    return lax.axis_index(axis)


def linear_axis_index(axes: Sequence[str], sizes: Sequence[int]):
    """Row-major linearized index over several mesh axes, in [0, prod(sizes))."""
    idx = 0
    for ax, size in zip(axes, sizes):
        idx = idx * size + lax.axis_index(ax)
    return idx


def psum(v, axes):
    """Single-array float/int psum. The sanctioned spelling of ``lax.psum``
    everywhere outside this module (linter rule C001): model-axis activation
    reductions and the scalar loss/metric reductions the wire auditor's
    W001 allowance covers. Gradient-sized dp-axis payloads do NOT belong
    here — they ride :func:`psum_wire_words` as integers."""
    return lax.psum(v, axes)


def pmax(v, axes):
    """Single-array pmax (see :func:`psum` for the C001 contract)."""
    return lax.pmax(v, axes)


def all_to_all(v, axis: str, *, split_axis: int, concat_axis: int,
               tiled: bool = False):
    """Portable ``lax.all_to_all`` (MoE expert-parallel shuffles)."""
    return lax.all_to_all(
        v, axis, split_axis=split_axis, concat_axis=concat_axis, tiled=tiled
    )


def psum_tree(x, axes):
    return jax.tree.map(lambda v: lax.psum(v, axes), x)


def _check_wire_dtypes(words):
    for v in jax.tree.leaves(words):
        if not jnp.issubdtype(v.dtype, jnp.integer):
            raise TypeError(
                f"wire payload must be integer, got {v.dtype} — the IntSGD "
                "wire carries no floats (route float reductions through "
                "psum_tree instead)"
            )


def psum_wire_words(words, axes):
    """The packed-word integer all-reduce — THE floatless-wire primitive.

    Every wire-codec transport (dense lanes, bit-packed int32 words) sums
    its payload through here; the dtype guard makes the paper's no-floats
    contract structural: a float leaf on the gradient wire is a bug, not a
    silent fallback. Wrap-around integer addition is exactly what the
    packed-field arithmetic needs (see repro/wire/packed.py).

    The whole tree rides ONE psum primitive — the serial reference the
    bucketed route (:func:`psum_wire_words_bucketed`) is measured against:
    one monolithic collective on the critical path vs many interleavable
    ones (benchmarks/bench_overlap.py counts exactly this).
    """
    _check_wire_dtypes(words)
    return lax.psum(words, axes)


def ring_allreduce_int(v, axis: str, n: int):
    """Integer all-reduce of one flat array as a ``lax.ppermute`` ring
    reduce-scatter followed by an all-gather (the SwitchML/NCCL shape).

    Why not one psum: a psum is a single opaque collective on the critical
    path. The ring decomposes it into n-1 chunk-sized ppermute hops plus a
    chunk all-gather — independent ops XLA's latency-hiding scheduler can
    overlap with pending compute (the next bucket's pack, the next
    microbatch's backward). Integer addition is exact and associative
    (wrap-around mod 2^width), so the ring sum is BIT-IDENTICAL to the psum
    for any hop order — dense lanes never overflow mid-ring (any partial sum
    of k <= n §5.1-clipped values fits the lane), packed words wrap exactly
    per field. Works under shard_map AND vmap(axis_name), like every other
    primitive here.
    """
    if n <= 1:
        return v
    size = v.size
    c = -(-size // n)  # ring chunk: pad only to a multiple of n
    chunks = jnp.pad(v.reshape(-1), (0, n * c - size)).reshape(n, c)
    i = lax.axis_index(axis)
    perm = [(d, (d + 1) % n) for d in range(n)]
    take = lambda j: lax.dynamic_index_in_dim(
        chunks, jnp.mod(j, n), 0, keepdims=False
    )
    # reduce-scatter: after step s the in-flight partial for chunk
    # (i - s - 2) mod n has accumulated s + 2 contributions; after n-1 steps
    # device i holds the full sum of chunk i.
    send = take(i - 1)
    for s in range(n - 1):
        recvd = lax.ppermute(send, axis, perm)
        send = recvd + take(i - s - 2)
    # all-gather of the finished chunks (device i contributed chunk i, so
    # the gathered rows are already in chunk order)
    out = lax.all_gather(send, axis)
    return out.reshape(-1)[:size].reshape(v.shape)


def psum_wire_words_bucketed(buckets, axes, sizes):
    """Bucketed async-capable integer all-reduce — the ``overlap`` wire.

    ``buckets`` is the list of fixed-size 1-D word buckets cut by
    :mod:`repro.wire.bucketing`; each is ring-reduced independently
    (sequentially over multi-axis dp grids: a ring per mesh axis), emitting
    2+ small collectives per bucket instead of one monolithic psum, so the
    XLA scheduler can double-buffer bucket k's wire time against whatever
    compute is still pending. Bit-identical to ``psum_wire_words`` on the
    debucketized tree (integer addition is exact in any order); same dtype
    guard — the floatless wire stays structural on the overlapped route.
    """
    _check_wire_dtypes(buckets)

    def _one(b):
        for ax, n in zip(axes, sizes):
            b = ring_allreduce_int(b, ax, n)
        return b

    return [_one(b) for b in buckets]


def allgather_wire_words(payload, axes, sizes):
    """Integer all-gather of a transport payload tree — the gather-shaped
    wire primitive (sparse codecs: value + index planes that must arrive
    intact because no cross-worker sum is meaningful on the wire).

    Same structural floatless-wire guard as :func:`psum_wire_words`; every
    plane comes back with a flat leading worker axis of size prod(sizes),
    ordered to match :func:`linear_axis_index` (row-major over `axes`, the
    same order :func:`all_gather_flat` uses). A size-1 axis short-circuits
    in Python and emits nothing, mirroring :func:`ring_allreduce_int` — the
    static transport model (`traffic.plan_transport`, gather branch) counts
    exactly the eqns emitted here.
    """
    _check_wire_dtypes(payload)
    pairs = tuple((ax, s) for ax, s in zip(axes, sizes))
    n = 1
    for _, s in pairs:
        n *= s

    def _one(v):
        out = v
        for ax, s in reversed(pairs):
            if s > 1:
                out = lax.all_gather(out, ax)
        return out.reshape((n,) + v.shape)

    return jax.tree.map(_one, payload)


def pmax_tree(x, axes):
    return jax.tree.map(lambda v: lax.pmax(v, axes), x)


def pmean_tree(x, axes, n: int):
    return jax.tree.map(lambda v: lax.psum(v, axes) / n, x)


def all_gather_flat(v, axes: Sequence[str], n: int):
    """Gather one array over `axes` with a flat leading worker axis of size n.

    Worker order matches :func:`linear_axis_index` (row-major over `axes`).
    """
    out = v
    for ax in reversed(tuple(axes)):
        out = lax.all_gather(out, ax)
    return out.reshape((n,) + v.shape)


def all_gather_concat(v, axes: Sequence[str], n: int):
    """Gather over `axes` concatenating along the existing leading dim
    (the ZeRO-1 bf16 param all-gather layout)."""
    return all_gather_flat(v, axes, n).reshape((-1,) + v.shape[1:])


def ppermute_ring(x, axis: str, n: int, *, shift: int = 1):
    """Send to the next device on a ring over `axis` (pipeline transfers)."""
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, axis, perm)


# ---------------------------------------------------------------------------
# single-device simulation of the worker axis
# ---------------------------------------------------------------------------
WORKER_AXIS = "workers"


def vmap_workers(fn, in_axes, *, axis: str = WORKER_AXIS):
    """vmap with an axis name: the n-worker simulation entry point. The axis
    primitives above lower identically under this and under shard_map, which
    is what lets CPU convergence tests validate the distributed algorithm."""
    return jax.vmap(fn, in_axes=in_axes, axis_name=axis)
