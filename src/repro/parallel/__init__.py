from repro.parallel.pp import pipeline_forward
