from repro.parallel import collectives
from repro.parallel.collectives import shard_map, sharded_jit
from repro.parallel.pp import pipeline_forward
