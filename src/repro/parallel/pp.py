"""Pipeline parallelism: GPipe-style microbatched stage execution inside
shard_map, with lax.ppermute activation transfers between neighbor stages.

Not part of the prescribed production mesh (pod/data/model); provided as the
scaling escape hatch for depth (e.g. >64-layer models at higher TP would
exceed HBM per stage) and validated by tests/test_pipeline.py on a forced
multi-device CPU mesh.

Schedule: classic GPipe fill-drain over n_micro microbatches; each device
holds L/n_stages layers. The steady-state bubble fraction is
(n_stages-1)/(n_micro+n_stages-1) — recorded in the §Roofline discussion.
IntSGD composes unchanged: PP gradients stay stage-local, and the
data-parallel integer all-reduce happens per stage shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel import collectives as coll


def pipeline_forward(layer_fn, stage_params, x_micro, *, axis: str, n_stages: int):
    """Run a layer stack split across `n_stages` devices over microbatches.

    layer_fn(params, x) -> x, applied to this stage's parameter slice.
    stage_params: this device's layer parameters (stacked leading dim
    L/n_stages — layer_fn is scanned over it).
    x_micro: (n_micro, mb, ...) microbatched input; only stage 0's value is
    used, other stages receive activations via ppermute.
    Returns (n_micro, mb, ...) outputs valid on the LAST stage.
    """
    n_micro = x_micro.shape[0]
    stage = coll.axis_index(axis)

    def stage_apply(x):
        def body(h, lp):
            return layer_fn(lp, h), None

        out, _ = lax.scan(body, x, stage_params)
        return out

    total = n_micro + n_stages - 1

    def tick(carry, t):
        outputs, inflight = carry
        # select this tick's input: stage 0 reads microbatch t, others read
        # the activation forwarded from the previous stage
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        my_in = jnp.where(
            (stage == 0)[None],
            x_micro[mb_idx],
            inflight,
        )
        active = (t - stage >= 0) & (t - stage < n_micro)
        out = stage_apply(my_in)
        out = jnp.where(active[None], out, jnp.zeros_like(out))
        # forward to next stage
        nxt = coll.ppermute_ring(out, axis, n_stages)
        # last stage records its finished microbatch
        done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
        record = (stage == n_stages - 1) & active
        outputs = outputs.at[done_idx].set(
            jnp.where(record[None], out, outputs[done_idx])
        )
        return (outputs, nxt), None

    outputs0 = jnp.zeros_like(x_micro)
    inflight0 = jnp.zeros_like(x_micro[0])
    (outputs, _), _ = lax.scan(tick, (outputs0, inflight0), jnp.arange(total))
    return outputs
