"""GQA attention: chunked (flash-style) causal training path, KV-cache decode
path, sliding-window support, optional QKV bias, and a distributed
online-softmax decode for sequence-parallel (SP) KV shards.

The training path streams KV in chunks with a running (max, sum, acc) online
softmax so per-device activation memory is O(T·d) instead of O(T²) — the
memory-roofline enabler for the 32k prefill shapes. Wrapped in jax.checkpoint
by the caller so the backward pass recomputes chunk scores.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel import collectives as coll

from repro.models.common import Axes, HeadLayout, dense_init, rope

NEG_INF = -1e30


def init_attn_params(key, d_model, layout: HeadLayout, *, bias=False, dtype=jnp.float32):
    """LOCAL parameter shard for one layer (shapes already divided by tp)."""
    ks = jax.random.split(key, 4)
    nq, nkv, dh = layout.q_local, layout.kv_local, layout.head_dim
    p = {
        "wq": dense_init(ks[0], (d_model, nq * dh), d_model, dtype),
        "wk": dense_init(ks[1], (d_model, nkv * dh), d_model, dtype),
        "wv": dense_init(ks[2], (d_model, nkv * dh), d_model, dtype),
        "wo": dense_init(ks[3], (nq * dh, d_model), nq * dh, dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((nq * dh,), dtype)
        p["bk"] = jnp.zeros((nkv * dh,), dtype)
        p["bv"] = jnp.zeros((nkv * dh,), dtype)
    return p


def _chunked_attn(
    q, k, v, q_pos, kv_pos, *, window: int | None, chunk: int, causal: bool = True
):
    """Online-softmax attention.
    q: (B, Tq, Hq, dh); k,v: (B, Tk, Hkv, dh); *_pos: (B, T) int32.
    Causal: q_pos >= kv_pos; window: kv_pos > q_pos - window.
    Returns (B, Tq, Hq, dh)."""
    b, tq, hq, dh = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    nchunks = (tk + chunk - 1) // chunk
    pad = nchunks * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    # (B, Hkv, group, Tq, dh) query view
    qh = q.reshape(b, tq, hkv, group, dh).transpose(0, 2, 3, 1, 4)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    kc = k.reshape(b, nchunks, chunk, hkv, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nchunks, chunk, hkv, dh).transpose(1, 0, 3, 2, 4)
    pc = kv_pos.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, s, acc = carry
        kb, vb, pb = xs  # (B,Hkv,chunk,dh), (B,Hkv,chunk,dh), (B,chunk)
        logits = (
            jnp.einsum("bhgqd,bhcd->bhgqc", qh.astype(jnp.float32), kb.astype(jnp.float32))
            * scale
        )
        if causal:
            mask = pb[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        else:
            mask = pb[:, None, None, None, :] < 2**29  # only exclude padding
        if window is not None:
            mask &= pb[:, None, None, None, :] > (
                q_pos[:, None, None, :, None] - window
            )
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, hkv, group, tq), NEG_INF, jnp.float32)
    s0 = jnp.zeros((b, hkv, group, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, tq, dh), jnp.float32)
    (m, s, acc), _ = lax.scan(body, (m0, s0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(s, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, dh).astype(q.dtype)


def attention_train(
    params,
    x,
    positions,
    axes: Axes,
    layout: HeadLayout,
    *,
    window: int | None = None,
    rope_theta: float = 10000.0,
    chunk: int = 1024,
):
    """Full causal self-attention over x: (B, T, d). Column-parallel QKV,
    row-parallel output proj."""
    b, t, _ = x.shape
    nq, nkv, dh = layout.q_local, layout.kv_local, layout.head_dim
    q = jnp.einsum("btd,dk->btk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dk->btk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dk->btk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = rope(q.reshape(b, t, nq, dh), positions, rope_theta)
    k = rope(k.reshape(b, t, nkv, dh), positions, rope_theta)
    v = v.reshape(b, t, nkv, dh)
    ckpt_attn = jax.checkpoint(
        partial(_chunked_attn, window=window, chunk=min(chunk, t))
    )
    out = ckpt_attn(q, k, v, positions, positions)
    out = jnp.einsum(
        "btk,kd->btd", out.reshape(b, t, nq * dh), params["wo"].astype(x.dtype)
    )
    return axes.psum_tp(out)


def attention_decode(
    params,
    x,
    pos,
    cache,
    axes: Axes,
    layout: HeadLayout,
    *,
    window: int | None = None,
    rope_theta: float = 10000.0,
):
    """One-token decode. x: (B, 1, d); pos: (B,) int32 current position.
    cache: {"k","v": (B, S_loc, Hkv_loc, dh), "kv_pos": (B, S_loc)}.
    If axes.sp is set the cache sequence dim is sharded over axes.sp and the
    softmax is combined across shards (distributed online softmax); the new
    KV is written only on the owning shard.
    Returns (out: (B,1,d), new_cache)."""
    b = x.shape[0]
    nq, nkv, dh = layout.q_local, layout.kv_local, layout.head_dim
    q = jnp.einsum("btd,dk->btk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dk->btk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dk->btk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = rope(q.reshape(b, 1, nq, dh), pos[:, None], rope_theta)
    k_new = rope(k.reshape(b, 1, nkv, dh), pos[:, None], rope_theta)
    v_new = v.reshape(b, 1, nkv, dh)

    s_loc = cache["k"].shape[1]
    if axes.sp:
        shard = axes.sp_index()
        slot = pos - shard * s_loc  # local write position
        write_ok = (slot >= 0) & (slot < s_loc)
    else:
        slot = pos
        write_ok = jnp.ones((b,), bool)
    slot_c = jnp.clip(slot, 0, s_loc - 1)
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slot_c].set(
        jnp.where(write_ok[:, None, None], k_new[:, 0], cache["k"][bidx, slot_c])
    )
    v_cache = cache["v"].at[bidx, slot_c].set(
        jnp.where(write_ok[:, None, None], v_new[:, 0], cache["v"][bidx, slot_c])
    )
    kv_pos = cache["kv_pos"].at[bidx, slot_c].set(
        jnp.where(write_ok, pos, cache["kv_pos"][bidx, slot_c])
    )

    group = nq // nkv
    qh = q.reshape(b, nkv, group, dh)  # Tq=1 folded away
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = (
        jnp.einsum(
            "bhgd,bshd->bhgs",
            qh.astype(jnp.float32),
            k_cache.astype(jnp.float32),
        )
        * scale
    )
    mask = kv_pos[:, None, None, :] <= pos[:, None, None, None]
    if window is not None:
        mask &= kv_pos[:, None, None, :] > (pos[:, None, None, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)
    m_loc = jnp.max(logits, axis=-1)
    if axes.sp:
        m = coll.pmax(m_loc, axes.sp)
    else:
        m = m_loc
    p = jnp.exp(logits - m[..., None])
    s = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    if axes.sp:
        s = coll.psum(s, axes.sp)
        acc = coll.psum(acc, axes.sp)
    out = (acc / jnp.maximum(s, 1e-30)[..., None]).reshape(b, 1, nq * dh)
    out = jnp.einsum("btk,kd->btd", out.astype(x.dtype), params["wo"].astype(x.dtype))
    out = axes.psum_tp(out)
    new_cache = dict(cache, k=k_cache, v=v_cache, kv_pos=kv_pos)
    return out, new_cache


def init_cache(b_local, s_local, layout: HeadLayout, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((b_local, s_local, layout.kv_local, layout.head_dim), dtype),
        "v": jnp.zeros((b_local, s_local, layout.kv_local, layout.head_dim), dtype),
        "kv_pos": jnp.full((b_local, s_local), 2**30, jnp.int32),
    }
