"""Multi-head Latent Attention (DeepSeek-V2) — low-rank KV compression.

Structure (per DeepSeek-V2 paper):
  c_kv = x @ W_dkv                      (T, kv_lora)      shared latent
  k_c, v = c_kv @ W_uk, c_kv @ W_uv     per-head decompress (TP-sharded)
  k_rope = x @ W_kr                     (T, dh_rope)      shared rotary key
  q      = x @ W_q  (per head: content part + rotary part)

The latent cache (c_kv + k_rope) is what decode stores — kv_lora(512) +
dh_rope(64) floats per token instead of 2·H·dh: the paper's KV-cache
compression. The latent projections are replicated (small); per-head
decompression matrices are column-parallel over TP.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import Axes, dense_init, rope
from repro.models.attention import _chunked_attn, NEG_INF

DH_ROPE = 64


def init_mla_params(
    key, d_model, n_heads_local, head_dim, kv_lora, dtype=jnp.float32
):
    ks = jax.random.split(key, 6)
    return {
        "w_dkv": dense_init(ks[0], (d_model, kv_lora), d_model, dtype),
        "w_kr": dense_init(ks[1], (d_model, DH_ROPE), d_model, dtype),
        "w_uk": dense_init(ks[2], (kv_lora, n_heads_local * head_dim), kv_lora, dtype),
        "w_uv": dense_init(ks[3], (kv_lora, n_heads_local * head_dim), kv_lora, dtype),
        "w_q": dense_init(
            ks[4], (d_model, n_heads_local * (head_dim + DH_ROPE)), d_model, dtype
        ),
        "wo": dense_init(
            ks[5], (n_heads_local * head_dim, d_model), n_heads_local * head_dim, dtype
        ),
    }


def _split_q(q, n_heads, head_dim):
    q = q.reshape(q.shape[:-1] + (n_heads, head_dim + DH_ROPE))
    return q[..., :head_dim], q[..., head_dim:]


def mla_train(
    params, x, positions, axes: Axes, *, n_heads_local, head_dim, chunk=1024
):
    b, t, _ = x.shape
    c_kv = jnp.einsum("btd,dl->btl", x, params["w_dkv"].astype(x.dtype))
    k_r = jnp.einsum("btd,dr->btr", x, params["w_kr"].astype(x.dtype))
    k_r = rope(k_r.reshape(b, t, 1, DH_ROPE), positions)
    k_c = jnp.einsum("btl,lk->btk", c_kv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("btl,lk->btk", c_kv, params["w_uv"].astype(x.dtype))
    q = jnp.einsum("btd,dk->btk", x, params["w_q"].astype(x.dtype))
    q_c, q_r = _split_q(q, n_heads_local, head_dim)
    q_r = rope(q_r, positions)
    # concat content + rotary parts; K rotary part shared across heads
    q_full = jnp.concatenate([q_c, q_r], axis=-1)
    k_full = jnp.concatenate(
        [
            k_c.reshape(b, t, n_heads_local, head_dim),
            jnp.broadcast_to(k_r, (b, t, n_heads_local, DH_ROPE)),
        ],
        axis=-1,
    )
    v = v.reshape(b, t, n_heads_local, head_dim)
    # pad V up to q/k feature dim for the shared chunked kernel, slice after
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, DH_ROPE)))
    ckpt = jax.checkpoint(partial(_chunked_attn, window=None, chunk=min(chunk, t)))
    out = ckpt(q_full, k_full, vpad, positions, positions)[..., :head_dim]
    out = jnp.einsum(
        "btk,kd->btd",
        out.reshape(b, t, n_heads_local * head_dim),
        params["wo"].astype(x.dtype),
    )
    return axes.psum_tp(out)


def init_mla_cache(b_local, s_local, kv_lora, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((b_local, s_local, kv_lora), dtype),
        "k_r": jnp.zeros((b_local, s_local, DH_ROPE), dtype),
        "kv_pos": jnp.full((b_local, s_local), 2**30, jnp.int32),
    }


def mla_decode(params, x, pos, cache, axes: Axes, *, n_heads_local, head_dim):
    """One-token decode against the latent cache. x: (B,1,d)."""
    b = x.shape[0]
    c_new = jnp.einsum("btd,dl->btl", x, params["w_dkv"].astype(x.dtype))[:, 0]
    k_r_new = jnp.einsum("btd,dr->btr", x, params["w_kr"].astype(x.dtype))
    k_r_new = rope(k_r_new.reshape(b, 1, 1, DH_ROPE), pos[:, None])[:, 0, 0]

    s_loc = cache["c_kv"].shape[1]
    bidx = jnp.arange(b)
    slot = jnp.clip(pos, 0, s_loc - 1)
    c_cache = cache["c_kv"].at[bidx, slot].set(c_new.astype(cache["c_kv"].dtype))
    kr_cache = cache["k_r"].at[bidx, slot].set(k_r_new.astype(cache["k_r"].dtype))
    kv_pos = cache["kv_pos"].at[bidx, slot].set(pos)

    # decompress cached latents (the flop trade the MLA paper makes)
    k_c = jnp.einsum("bsl,lk->bsk", c_cache.astype(x.dtype), params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsl,lk->bsk", c_cache.astype(x.dtype), params["w_uv"].astype(x.dtype))
    k_c = k_c.reshape(b, s_loc, n_heads_local, head_dim)
    v = v.reshape(b, s_loc, n_heads_local, head_dim)

    q = jnp.einsum("btd,dk->btk", x, params["w_q"].astype(x.dtype))
    q_c, q_r = _split_q(q, n_heads_local, head_dim)
    q_r = rope(q_r, pos[:, None])
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim + DH_ROPE, jnp.float32))
    logits = jnp.einsum(
        "bhd,bshd->bhs", q_c[:, 0].astype(jnp.float32), k_c.astype(jnp.float32)
    )
    logits += jnp.einsum(
        "bhr,bsr->bhs", q_r[:, 0].astype(jnp.float32), kr_cache.astype(jnp.float32)
    )
    logits *= scale
    mask = kv_pos[:, None, :] <= pos[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, n_heads_local * head_dim).astype(x.dtype)
    out = jnp.einsum("btk,kd->btd", out, params["wo"].astype(x.dtype))
    return axes.psum_tp(out), dict(cache, c_kv=c_cache, k_r=kr_cache, kv_pos=kv_pos)
