"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel train / recurrent
decode) and sLSTM (scalar memory, sequential scan).

mLSTM recurrence (per head, stabilizer-free GLA-style form; gates clamped):
    C_t = f_t C_{t-1} + i_t (k_t ⊗ v_t)        C: (dh, dh)
    n_t = f_t n_{t-1} + i_t k_t                n: (dh,)
    y_t = (q_t C_t) / max(|q_t·n_t|, 1)

with f_t = sigmoid(f̃_t) ∈ (0,1), i_t = exp(min(ĩ_t, 0)). Chunked training:
within-chunk quadratic masked form with cumulative log-f decay, inter-chunk
state carried by lax.scan — the same structure as Mamba2's SSD, which is why
both live in the sub-quadratic family that runs long_500k.

sLSTM: per-head scalar-memory cell with exponential gating and a recurrent
(block-diagonal per head) hidden projection — lax.scan over time.

TP: heads sharded over the model axis; out-proj row-parallel (+psum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import Axes, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm_params(key, d_model, n_heads_local, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    dk = n_heads_local * head_dim
    return {
        "w_q": dense_init(ks[0], (d_model, dk), d_model, dtype),
        "w_k": dense_init(ks[1], (d_model, dk), d_model, dtype),
        "w_v": dense_init(ks[2], (d_model, dk), d_model, dtype),
        "w_if": dense_init(ks[3], (d_model, 2 * n_heads_local), d_model, dtype),
        "if_bias": jnp.concatenate(
            [jnp.full((n_heads_local,), -2.0, dtype), jnp.full((n_heads_local,), 3.0, dtype)]
        ),
        "norm_w": jnp.ones((dk,), dtype),
        "w_out": dense_init(ks[4], (dk, d_model), dk, dtype),
    }


def _mlstm_chunk(carry, xs):
    """carry: C (B,H,dh,dh), n (B,H,dh). xs per chunk: q,k,v (B,Q,H,dh),
    logf (B,Q,H), logi (B,Q,H)."""
    C, nvec = carry
    q, k, v, logf, logi = xs
    s = jnp.cumsum(logf, axis=1)  # (B,Q,H) cumulative log forget
    # intra-chunk: A[t,τ] = exp(s_t - s_τ + logi_τ) (q_t·k_τ), τ<=t
    qk = jnp.einsum("bthd,bshd->bths", q, k)
    decay = jnp.exp(
        jnp.clip(s[:, :, None, :] - s[:, None, :, :] + logi[:, None, :, :], -60.0, 30.0)
    )
    qlen = q.shape[1]
    causal = jnp.tril(jnp.ones((qlen, qlen), bool))
    att = jnp.where(causal[None, :, :, None], qk.transpose(0, 1, 3, 2) * decay, 0.0)
    y_intra = jnp.einsum("btsh,bshd->bthd", att, v)
    n_intra = jnp.einsum("btsh,bshd->bthd", att, k)
    # inter-chunk
    w_t = jnp.exp(jnp.clip(s, -60.0, 0.0))  # (B,Q,H)
    y_inter = w_t[..., None] * jnp.einsum("bthd,bhde->bthe", q, C)
    n_inter = w_t[..., None] * nvec[:, None, :, :]
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_intra + n_inter)), 1.0
    )
    y = (y_intra + y_inter) / denom[..., None]
    # state update
    w_last = jnp.exp(jnp.clip(s[:, -1:, :] - s + logi, -60.0, 30.0))  # (B,Q,H)
    dC = jnp.einsum("bqh,bqhd,bqhe->bhde", w_last, k, v)
    dn = jnp.einsum("bqh,bqhd->bhd", w_last, k)
    f_all = jnp.exp(jnp.clip(s[:, -1, :], -60.0, 0.0))
    C_new = f_all[:, :, None, None] * C + dC
    n_new = f_all[:, :, None] * nvec + dn
    return (C_new, n_new), y


def mlstm_train(params, x, axes: Axes, *, n_heads_local, head_dim, chunk=256):
    b, t, _ = x.shape
    h, dh = n_heads_local, head_dim
    to = lambda w: jnp.einsum("btd,dk->btk", x, w.astype(x.dtype)).astype(jnp.float32)
    q = to(params["w_q"]).reshape(b, t, h, dh) / jnp.sqrt(float(dh))
    k = to(params["w_k"]).reshape(b, t, h, dh) / jnp.sqrt(float(dh))
    v = to(params["w_v"]).reshape(b, t, h, dh)
    gi = to(params["w_if"]) + params["if_bias"].astype(jnp.float32)
    logi = jnp.minimum(gi[..., :h], 0.0)
    logf = jax.nn.log_sigmoid(gi[..., h:])
    qc = min(chunk, t)
    assert t % qc == 0
    nch = t // qc
    resh = lambda a: a.reshape((b, nch, qc) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1))
    )
    xs = (resh(q), resh(k), resh(v), resh(logf), resh(logi))
    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    step = jax.checkpoint(_mlstm_chunk)
    _, ys = lax.scan(step, (C0, n0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h * dh).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out)


def init_mlstm_cache(b_local, n_heads_local, head_dim):
    return {
        "C": jnp.zeros((b_local, n_heads_local, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((b_local, n_heads_local, head_dim), jnp.float32),
    }


def mlstm_decode(params, x, cache, axes: Axes, *, n_heads_local, head_dim):
    b = x.shape[0]
    h, dh = n_heads_local, head_dim
    to = lambda w: jnp.einsum("bd,dk->bk", x[:, 0], w.astype(x.dtype)).astype(jnp.float32)
    q = to(params["w_q"]).reshape(b, h, dh) / jnp.sqrt(float(dh))
    k = to(params["w_k"]).reshape(b, h, dh) / jnp.sqrt(float(dh))
    v = to(params["w_v"]).reshape(b, h, dh)
    gi = to(params["w_if"]) + params["if_bias"].astype(jnp.float32)
    i_g = jnp.exp(jnp.minimum(gi[..., :h], 0.0))
    f_g = jax.nn.sigmoid(gi[..., h:])
    C = f_g[:, :, None, None] * cache["C"] + i_g[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v
    )
    nv = f_g[:, :, None] * cache["n"] + i_g[:, :, None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, nv)), 1.0)
    y = jnp.einsum("bhd,bhde->bhe", q, C) / denom[..., None]
    y = y.reshape(b, 1, h * dh).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out), {"C": C, "n": nv}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm_params(key, d_model, n_heads_local, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    dk = n_heads_local * head_dim
    return {
        "w_in": dense_init(ks[0], (d_model, 4 * dk), d_model, dtype),
        "r_h": dense_init(ks[1], (n_heads_local, head_dim, 4 * head_dim), head_dim, dtype),
        "b": jnp.zeros((4 * dk,), dtype),
        "norm_w": jnp.ones((dk,), dtype),
        "w_out": dense_init(ks[2], (dk, d_model), dk, dtype),
    }


def _slstm_cell(params, h_prev, c_prev, zx, n_heads_local, head_dim):
    """zx: (B, 4*dk) pre-activation from input; h/c: (B, H, dh)."""
    hh = jnp.einsum("bhd,hde->bhe", h_prev, params["r_h"].astype(h_prev.dtype))
    z = zx.reshape(zx.shape[0], n_heads_local, 4 * head_dim) + hh
    zi, zf, zg, zo = jnp.split(z, 4, axis=-1)
    i_g = jnp.exp(jnp.minimum(zi, 0.0))
    f_g = jax.nn.sigmoid(zf)
    c = f_g * c_prev + i_g * jnp.tanh(zg)
    o = jax.nn.sigmoid(zo)
    h = o * jnp.tanh(c)
    return h, c


def slstm_train(params, x, axes: Axes, *, n_heads_local, head_dim):
    b, t, _ = x.shape
    h_loc, dh = n_heads_local, head_dim
    zx = (
        jnp.einsum("btd,dk->btk", x, params["w_in"].astype(x.dtype))
        + params["b"].astype(x.dtype)
    ).astype(jnp.float32)

    def step(carry, z_t):
        h, c = carry
        h2, c2 = _slstm_cell(params, h, c, z_t, h_loc, dh)
        return (h2, c2), h2

    h0 = jnp.zeros((b, h_loc, dh), jnp.float32)
    c0 = jnp.zeros((b, h_loc, dh), jnp.float32)
    _, hs = lax.scan(step, (h0, c0), zx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, t, h_loc * dh).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out)


def init_slstm_cache(b_local, n_heads_local, head_dim):
    return {
        "h": jnp.zeros((b_local, n_heads_local, head_dim), jnp.float32),
        "c": jnp.zeros((b_local, n_heads_local, head_dim), jnp.float32),
    }


def slstm_decode(params, x, cache, axes: Axes, *, n_heads_local, head_dim):
    b = x.shape[0]
    zx = (
        jnp.einsum("bd,dk->bk", x[:, 0], params["w_in"].astype(x.dtype))
        + params["b"].astype(x.dtype)
    ).astype(jnp.float32)
    h, c = _slstm_cell(params, cache["h"], cache["c"], zx, n_heads_local, head_dim)
    y = h.reshape(b, 1, n_heads_local * head_dim).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out), {"h": h, "c": c}
