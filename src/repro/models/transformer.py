"""Decoder-only LM assembly covering the dense / MoE / MLA / hybrid-SSM /
xLSTM / VLM families, with scan-over-layers and TP-aware modules.

Parameter creation is parameterized by ``n_shards`` ∈ {1, tp}: with
n_shards=1 you get the GLOBAL (padded-for-tp) shapes, with n_shards=tp the
LOCAL per-device shard shapes. launch/specs.py derives PartitionSpecs by
diffing the two shape trees — no hand-maintained sharding table can drift
out of sync with the model code.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (
    Axes,
    HeadLayout,
    dense_init,
    embed_lookup,
    pad_to_multiple,
    plan_heads,
    rmsnorm,
    tp_cross_entropy,
)
from repro.models.mlp import init_swiglu, swiglu_mlp


@dataclasses.dataclass(frozen=True)
class Dims:
    """All local tensor dims for a given (cfg, tp, n_shards)."""

    layout: HeadLayout
    d_ff_loc: int
    vocab_loc: int
    # moe
    e_loc: int = 0
    ff_e_loc: int = 0
    ff_shared_loc: int = 0
    # ssm
    ssm_heads_loc: int = 0
    ssm_head_dim: int = 64
    # xlstm
    xl_heads_loc: int = 0
    xl_head_dim: int = 0


def resolve_dims(cfg, tp: int, n_shards: int) -> Dims:
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    layout_g = plan_heads(cfg.n_heads, cfg.n_kv_heads, head_dim, tp)
    layout = HeadLayout(
        layout_g.n_q,
        layout_g.n_kv,
        head_dim,
        layout_g.n_q // n_shards,
        layout_g.n_kv // n_shards,
    )
    d_ff_pad = pad_to_multiple(max(cfg.d_ff, tp), tp)
    vocab_pad = pad_to_multiple(cfg.vocab, tp)
    kw = {}
    if cfg.n_experts:
        strategy = moe_mod.pick_strategy(cfg.n_experts, tp)
        if strategy == "ep":
            kw["e_loc"] = cfg.n_experts // n_shards
            kw["ff_e_loc"] = cfg.d_ff
        else:
            kw["e_loc"] = cfg.n_experts
            kw["ff_e_loc"] = pad_to_multiple(cfg.d_ff, tp) // n_shards
        if cfg.n_shared_experts:
            ff_sh = pad_to_multiple(cfg.d_ff * cfg.n_shared_experts, tp)
            kw["ff_shared_loc"] = ff_sh // n_shards
    if cfg.ssm_state:
        d_inner = 2 * cfg.d_model
        heads = d_inner // 64
        kw["ssm_heads_loc"] = pad_to_multiple(heads, tp) // n_shards
        kw["ssm_head_dim"] = 64
    if cfg.family == "ssm":  # xlstm
        kw["xl_heads_loc"] = pad_to_multiple(cfg.n_heads, tp) // n_shards
        kw["xl_head_dim"] = head_dim
    return Dims(
        layout=layout,
        d_ff_loc=d_ff_pad // n_shards,
        vocab_loc=vocab_pad // n_shards,
        **kw,
    )


# ---------------------------------------------------------------------------
# per-layer init/apply
# ---------------------------------------------------------------------------
def _init_dense_layer(key, cfg, dims: Dims, dtype):
    ks = jax.random.split(key, 2)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_swiglu(ks[1], cfg.d_model, dims.d_ff_loc, dtype),
    }
    if cfg.kv_lora:
        p["attn"] = mla_mod.init_mla_params(
            ks[0], cfg.d_model, dims.layout.q_local, dims.layout.head_dim, cfg.kv_lora, dtype
        )
    else:
        p["attn"] = attn.init_attn_params(
            ks[0], cfg.d_model, dims.layout, bias=cfg.qkv_bias, dtype=dtype
        )
    return p


def _init_moe_layer(key, cfg, dims: Dims, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": {
            "router": dense_init(ks[1], (cfg.d_model, cfg.n_experts), cfg.d_model, jnp.float32),
            "w_gate": dense_init(ks[2], (dims.e_loc, cfg.d_model, dims.ff_e_loc), cfg.d_model, dtype),
            "w_up": dense_init(ks[2], (dims.e_loc, cfg.d_model, dims.ff_e_loc), cfg.d_model, dtype),
            "w_down": dense_init(ks[2], (dims.e_loc, dims.ff_e_loc, cfg.d_model), dims.ff_e_loc, dtype),
        },
    }
    if cfg.n_shared_experts:
        p["moe"]["shared"] = init_swiglu(ks[0], cfg.d_model, dims.ff_shared_loc, dtype)
    if cfg.kv_lora:
        p["attn"] = mla_mod.init_mla_params(
            ks[0], cfg.d_model, dims.layout.q_local, dims.layout.head_dim, cfg.kv_lora, dtype
        )
    else:
        p["attn"] = attn.init_attn_params(
            ks[0], cfg.d_model, dims.layout, bias=cfg.qkv_bias, dtype=dtype
        )
    return p


def _apply_attn_train(p, x, positions, axes, cfg, dims):
    if cfg.kv_lora:
        return mla_mod.mla_train(
            p, x, positions, axes,
            n_heads_local=dims.layout.q_local, head_dim=dims.layout.head_dim,
        )
    return attn.attention_train(
        p, x, positions, axes, dims.layout,
        window=cfg.window, rope_theta=cfg.rope_theta,
    )


def _dense_layer(p, x, positions, axes, cfg, dims):
    h = x + _apply_attn_train(p["attn"], rmsnorm(x, p["ln1"]), positions, axes, cfg, dims)
    h = h + swiglu_mlp(p["mlp"], rmsnorm(h, p["ln2"]), axes)
    return h


def _moe_layer(p, x, positions, axes, cfg, dims):
    h = x + _apply_attn_train(p["attn"], rmsnorm(x, p["ln1"]), positions, axes, cfg, dims)
    h = h + moe_mod.moe_block(
        p["moe"], rmsnorm(h, p["ln2"]), axes,
        n_experts=cfg.n_experts, top_k=cfg.top_k,
    )
    return h


# ---- zamba2-style hybrid: mamba backbone + shared attention block ----------
def _init_mamba_layer(key, cfg, dims: Dims, dtype):
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "m": ssm_mod.init_mamba2_params(
            key, cfg.d_model, dims.ssm_heads_loc, dims.ssm_head_dim, cfg.ssm_state, dtype
        ),
    }


def _init_shared_attn(key, cfg, dims: Dims, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((2 * cfg.d_model,), dtype),
        "w_in": dense_init(ks[0], (2 * cfg.d_model, cfg.d_model), 2 * cfg.d_model, dtype),
        "attn": attn.init_attn_params(ks[1], cfg.d_model, dims.layout, dtype=dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_swiglu(ks[2], cfg.d_model, dims.d_ff_loc, dtype),
    }


def _shared_attn_block(p, h, emb, positions, axes, cfg, dims):
    z = jnp.concatenate([h, emb], axis=-1)
    z = rmsnorm(z, p["ln"])
    z = jnp.einsum("btd,dk->btk", z, p["w_in"].astype(z.dtype))
    z = z + attn.attention_train(
        p["attn"], z, positions, axes, dims.layout, rope_theta=cfg.rope_theta
    )
    z = z + swiglu_mlp(p["mlp"], rmsnorm(z, p["ln2"]), axes)
    return h + z


# ---- xlstm blocks -----------------------------------------------------------
def _init_xlstm_block(key, cfg, dims: Dims, dtype):
    """One (mLSTM, mLSTM, sLSTM) block."""
    ks = jax.random.split(key, 3)
    mk = lambda k: {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "cell": xlstm_mod.init_mlstm_params(
            k, cfg.d_model, dims.xl_heads_loc, dims.xl_head_dim, dtype
        ),
    }
    return {
        "m1": mk(ks[0]),
        "m2": mk(ks[1]),
        "s": {
            "ln": jnp.ones((cfg.d_model,), dtype),
            "cell": xlstm_mod.init_slstm_params(
                ks[2], cfg.d_model, dims.xl_heads_loc, dims.xl_head_dim, dtype
            ),
        },
    }


def _xlstm_block(p, x, axes, cfg, dims):
    kw = dict(n_heads_local=dims.xl_heads_loc, head_dim=dims.xl_head_dim)
    x = x + xlstm_mod.mlstm_train(p["m1"]["cell"], rmsnorm(x, p["m1"]["ln"]), axes, **kw)
    x = x + xlstm_mod.mlstm_train(p["m2"]["cell"], rmsnorm(x, p["m2"]["ln"]), axes, **kw)
    x = x + xlstm_mod.slstm_train(p["s"]["cell"], rmsnorm(x, p["s"]["ln"]), axes, **kw)
    return x


# ---------------------------------------------------------------------------
# full model init
# ---------------------------------------------------------------------------
def init_lm_params(key, cfg, tp: int = 1, n_shards: int = 1, dtype=jnp.float32):
    dims = resolve_dims(cfg, tp, n_shards)
    keys = jax.random.split(key, 8)
    params = {
        "embed": dense_init(keys[0], (dims.vocab_loc, cfg.d_model), cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, dims.vocab_loc), cfg.d_model, dtype
        )
    if cfg.family in ("dense", "vlm"):
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_dense_layer(k, cfg, dims, dtype)
        )(lk)
    elif cfg.family == "moe":
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_moe_layer(k, cfg, dims, dtype))(lk)
    elif cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.attn_every
        lk = jax.random.split(keys[2], cfg.n_layers)
        stacked = jax.vmap(lambda k: _init_mamba_layer(k, cfg, dims, dtype))(lk)
        params["layers"] = jax.tree.map(
            lambda x: x.reshape((nb, cfg.attn_every) + x.shape[1:]), stacked
        )
        params["shared_attn"] = _init_shared_attn(keys[3], cfg, dims, dtype)
    elif cfg.family == "ssm":
        nb = cfg.n_layers // 3
        lk = jax.random.split(keys[2], nb)
        params["layers"] = jax.vmap(lambda k: _init_xlstm_block(k, cfg, dims, dtype))(lk)
    else:
        raise ValueError(cfg.family)
    if cfg.frontend == "vit":
        params["frontend_proj"] = dense_init(
            keys[4], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim, dtype
        )
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def _remat(cfg):
    """Layer-granularity rematerialization with an optional policy that
    saves TP psum outputs (skips re-running collectives in backward)."""
    if getattr(cfg, "remat_policy", "full") == "save_psum":
        return partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.save_only_these_names("tp_psum"),
        )
    return jax.checkpoint


def _embed_inputs(params, batch, axes, cfg):
    """Returns (x (B,T,d), positions (B,T))."""
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, axes)
    if cfg.frontend == "vit":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = jnp.einsum("bnd,dk->bnk", pe, params["frontend_proj"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    return x, positions


def lm_forward(params, batch, axes: Axes, cfg, dtype=jnp.bfloat16):
    """Returns hidden states after final norm: (B, T', d)."""
    tp = axes.tp_size
    dims = resolve_dims(cfg, tp, tp)
    x, positions = _embed_inputs(params, batch, axes, cfg)
    x = x.astype(dtype)

    if cfg.family in ("dense", "vlm", "moe"):
        layer_fn = _dense_layer if cfg.family != "moe" else _moe_layer

        ckpt = _remat(cfg)

        def body(h, lp):
            h = ckpt(
                lambda hh, pp: layer_fn(pp, hh, positions, axes, cfg, dims)
            )(h, lp)
            return h, None

        x, _ = lax.scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        emb0 = x

        def mamba_body(h, lp):
            h = h + ssm_mod.mamba2_train(
                lp["m"], rmsnorm(h, lp["ln"]), axes,
                n_heads_local=dims.ssm_heads_loc, head_dim=dims.ssm_head_dim,
                d_state=cfg.ssm_state,
            )
            return h, None

        def block_body(h, bp):
            h, _ = lax.scan(mamba_body, h, bp)
            h = _shared_attn_block(
                params["shared_attn"], h, emb0, positions, axes, cfg, dims
            )
            return h, None

        x, _ = lax.scan(block_body, x, params["layers"])
    elif cfg.family == "ssm":

        def body(h, bp):
            return _xlstm_block(bp, h, axes, cfg, dims), None

        x, _ = lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["ln_f"])


def lm_logits_local(params, h, cfg):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("btd,dv->btv", h, head.astype(h.dtype)).astype(jnp.float32)


def lm_loss(params, batch, axes: Axes, cfg, dtype=jnp.bfloat16):
    h = lm_forward(params, batch, axes, cfg, dtype)
    if cfg.frontend == "vit":  # only text positions carry labels
        h = h[:, -batch["tokens"].shape[1] :]
    logits = lm_logits_local(params, h, cfg)
    labels = batch["labels"]
    per_tok = tp_cross_entropy(logits, labels, axes)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
