"""Single-token decode (serve) paths for every family, with KV/state caches.

Cache layout per family (leading L or block axis scanned with the layers):
  dense/vlm : {"layers": {"k","v","kv_pos"}}                    (GQA KV)
  moe+mla   : {"layers": {"c_kv","k_r","kv_pos"}}               (MLA latent)
  hybrid    : {"mamba": conv/h stacked (nb, per, ...),
               "attn": KV per shared-attn application (nb, ...)}
  ssm/xlstm : {"blocks": {"m1","m2","s"} recurrent states}

The decode step lowers as `serve_step` in the dry-run for `decode_*` and
`long_*` shapes. For long_500k (batch=1) the KV sequence dim is sharded over
the data axis (axes.sp) with a distributed online softmax in attention.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import Axes, embed_lookup, rmsnorm
from repro.models.mlp import swiglu_mlp
from repro.models.transformer import (
    lm_logits_local,
    resolve_dims,
)


def init_lm_cache(cfg, tp: int, n_shards: int, b_local: int, s_local: int, dtype=jnp.bfloat16):
    dims = resolve_dims(cfg, tp, n_shards)
    L = cfg.n_layers

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    if cfg.family in ("dense", "vlm"):
        if cfg.kv_lora:
            base = mla_mod.init_mla_cache(b_local, s_local, cfg.kv_lora, dtype)
        else:
            base = attn.init_cache(b_local, s_local, dims.layout, dtype)
        return {"layers": stack(base, L)}
    if cfg.family == "moe":
        if cfg.kv_lora:
            base = mla_mod.init_mla_cache(b_local, s_local, cfg.kv_lora, dtype)
        else:
            base = attn.init_cache(b_local, s_local, dims.layout, dtype)
        return {"layers": stack(base, L)}
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.attn_every
        m = ssm_mod.init_mamba2_cache(
            b_local, dims.ssm_heads_loc, dims.ssm_head_dim, cfg.ssm_state
        )
        a = attn.init_cache(b_local, s_local, dims.layout, dtype)
        return {
            "mamba": stack(stack(m, cfg.attn_every), nb),
            "attn": stack(a, nb),
        }
    if cfg.family == "ssm":
        nb = cfg.n_layers // 3
        blk = {
            "m1": xlstm_mod.init_mlstm_cache(b_local, dims.xl_heads_loc, dims.xl_head_dim),
            "m2": xlstm_mod.init_mlstm_cache(b_local, dims.xl_heads_loc, dims.xl_head_dim),
            "s": xlstm_mod.init_slstm_cache(b_local, dims.xl_heads_loc, dims.xl_head_dim),
        }
        return {"blocks": stack(blk, nb)}
    raise ValueError(cfg.family)


def _attn_decode_any(lp, h, pos, lc, axes, cfg, dims):
    if cfg.kv_lora:
        return mla_mod.mla_decode(
            lp, h, pos, lc, axes,
            n_heads_local=dims.layout.q_local, head_dim=dims.layout.head_dim,
        )
    return attn.attention_decode(
        lp, h, pos, lc, axes, dims.layout,
        window=cfg.window, rope_theta=cfg.rope_theta,
    )


def lm_decode_step(params, cache, tokens, pos, axes: Axes, cfg, dtype=jnp.bfloat16):
    """tokens: (B,) int32 ids of the current step; pos: (B,) positions.
    Returns (logits_local (B, V/tp) f32, new_cache)."""
    tp = axes.tp_size
    dims = resolve_dims(cfg, tp, tp)
    x = embed_lookup(params["embed"], tokens[:, None], axes).astype(dtype)

    if cfg.family in ("dense", "vlm", "moe"):

        def body(h, xs):
            lp, lc = xs
            a, new_lc = _attn_decode_any(
                lp["attn"], rmsnorm(h, lp["ln1"]), pos, lc, axes, cfg, dims
            )
            h = h + a
            z = rmsnorm(h, lp["ln2"])
            if cfg.family == "moe":
                h = h + moe_mod.moe_block(
                    lp["moe"], z, axes, n_experts=cfg.n_experts, top_k=cfg.top_k
                )
            else:
                h = h + swiglu_mlp(lp["mlp"], z, axes)
            return h, new_lc

        x, new_layers = lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}
    elif cfg.family == "hybrid":
        emb0 = x

        def mamba_body(h, xs):
            lp, lc = xs
            out, new_lc = ssm_mod.mamba2_decode(
                lp["m"], rmsnorm(h, lp["ln"]), lc, axes,
                n_heads_local=dims.ssm_heads_loc, head_dim=dims.ssm_head_dim,
                d_state=cfg.ssm_state,
            )
            return h + out, new_lc

        sp = params["shared_attn"]

        def block_body(h, xs):
            bp, bc_m, bc_a = xs
            h, new_m = lax.scan(mamba_body, h, (bp, bc_m))
            z = jnp.concatenate([h, emb0], axis=-1)
            z = rmsnorm(z, sp["ln"])
            z = jnp.einsum("btd,dk->btk", z, sp["w_in"].astype(z.dtype))
            a, new_a = attn.attention_decode(
                sp["attn"], z, pos, bc_a, axes, dims.layout, rope_theta=cfg.rope_theta
            )
            z = z + a
            z = z + swiglu_mlp(sp["mlp"], rmsnorm(z, sp["ln2"]), axes)
            return h + z, (new_m, new_a)

        x, (new_m, new_a) = lax.scan(
            block_body, x, (params["layers"], cache["mamba"], cache["attn"])
        )
        new_cache = {"mamba": new_m, "attn": new_a}
    elif cfg.family == "ssm":
        kw = dict(n_heads_local=dims.xl_heads_loc, head_dim=dims.xl_head_dim)

        def body(h, xs):
            bp, bc = xs
            o, c1 = xlstm_mod.mlstm_decode(
                bp["m1"]["cell"], rmsnorm(h, bp["m1"]["ln"]), bc["m1"], axes, **kw
            )
            h = h + o
            o, c2 = xlstm_mod.mlstm_decode(
                bp["m2"]["cell"], rmsnorm(h, bp["m2"]["ln"]), bc["m2"], axes, **kw
            )
            h = h + o
            o, c3 = xlstm_mod.slstm_decode(
                bp["s"]["cell"], rmsnorm(h, bp["s"]["ln"]), bc["s"], axes, **kw
            )
            h = h + o
            return h, {"m1": c1, "m2": c2, "s": c3}

        x, new_blocks = lax.scan(body, x, (params["layers"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(x, params["ln_f"])
    logits = lm_logits_local(params, h, cfg)[:, 0]
    return logits, new_cache


def tp_greedy(logits_local, axes: Axes):
    """Greedy token from vocab-sharded logits without gathering them."""
    v_local = logits_local.shape[-1]
    local_best = jnp.argmax(logits_local, axis=-1)
    local_val = jnp.take_along_axis(logits_local, local_best[..., None], axis=-1)[..., 0]
    global_id = local_best + axes.tp_index() * v_local
    gmax = axes.pmax_tp(local_val)
    winner = jnp.where(local_val >= gmax, global_id, 0)
    return axes.psum_tp(winner) if axes.tp else winner
