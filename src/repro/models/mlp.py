"""Dense MLP blocks: SwiGLU (llama family) and GeLU (classic), TP-sharded
column→row parallel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Axes, dense_init, swiglu


def init_swiglu(key, d_model, d_ff_local, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff_local), d_model, dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff_local), d_model, dtype),
        "w_down": dense_init(ks[2], (d_ff_local, d_model), d_ff_local, dtype),
    }


def swiglu_mlp(params, x, axes: Axes):
    g = jnp.einsum("btd,df->btf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("btd,df->btf", x, params["w_up"].astype(x.dtype))
    h = swiglu(g, u)
    out = jnp.einsum("btf,fd->btd", h, params["w_down"].astype(x.dtype))
    return axes.psum_tp(out)


def init_gelu(key, d_model, d_ff_local, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], (d_model, d_ff_local), d_model, dtype),
        "b_in": jnp.zeros((d_ff_local,), dtype),
        "w_out": dense_init(ks[1], (d_ff_local, d_model), d_ff_local, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x, axes: Axes):
    h = jnp.einsum("btd,df->btf", x, params["w_in"].astype(x.dtype))
    h = jax.nn.gelu(h + params["b_in"].astype(h.dtype))
    out = jnp.einsum("btf,fd->btd", h, params["w_out"].astype(x.dtype))
    out = axes.psum_tp(out)
    return out + params["b_out"].astype(out.dtype)
