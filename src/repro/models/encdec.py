"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional self-attention over precomputed audio-frame
embeddings (the modality frontend is a stub per the assignment — the dry-run
``input_specs`` supplies (B, T_src, frontend_dim) frames). Decoder: causal
self-attention + cross-attention to encoder states, teacher-forced CE.

Decode path caches per-layer self-attention KV plus the cross-attention KV
projected once from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models.attention import _chunked_attn, NEG_INF
from repro.models.common import (
    Axes,
    dense_init,
    embed_lookup,
    layernorm,
    rope,
    tp_cross_entropy,
)
from repro.models.mlp import gelu_mlp, init_gelu
from repro.models.transformer import resolve_dims


def _init_ln(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(x, p):
    return layernorm(x, p["w"], p["b"])


def _init_enc_layer(key, cfg, dims, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "attn": attn.init_attn_params(ks[0], cfg.d_model, dims.layout, dtype=dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "mlp": init_gelu(ks[1], cfg.d_model, dims.d_ff_loc, dtype),
    }


def _init_dec_layer(key, cfg, dims, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "self_attn": attn.init_attn_params(ks[0], cfg.d_model, dims.layout, dtype=dtype),
        "ln_x": _init_ln(cfg.d_model, dtype),
        "cross_attn": attn.init_attn_params(ks[1], cfg.d_model, dims.layout, dtype=dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "mlp": init_gelu(ks[2], cfg.d_model, dims.d_ff_loc, dtype),
    }


def init_encdec_params(key, cfg, tp: int = 1, n_shards: int = 1, dtype=jnp.float32):
    dims = resolve_dims(cfg, tp, n_shards)
    ks = jax.random.split(key, 6)
    ek = jax.random.split(ks[0], cfg.enc_layers)
    dk = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "frontend_proj": dense_init(ks[2], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim, dtype),
        "embed": dense_init(ks[3], (dims.vocab_loc, cfg.d_model), cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dims, dtype))(ek),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dims, dtype))(dk),
        "ln_enc": _init_ln(cfg.d_model, dtype),
        "ln_dec": _init_ln(cfg.d_model, dtype),
        "lm_head": dense_init(ks[4], (cfg.d_model, dims.vocab_loc), cfg.d_model, dtype),
    }


def _cross_attention(p, x, enc_kv, q_pos, kv_pos, axes, dims):
    """x: (B,Tq,d); enc_kv: (k,v) precomputed (B,Ts,Hkv_loc,dh)."""
    b, tq, _ = x.shape
    nq, dh = dims.layout.q_local, dims.layout.head_dim
    q = jnp.einsum("btd,dk->btk", x, p["wq"].astype(x.dtype)).reshape(b, tq, nq, dh)
    k, v = enc_kv
    out = _chunked_attn(
        q, k, v, q_pos, kv_pos, window=None, chunk=min(1024, k.shape[1]), causal=False
    )
    out = jnp.einsum(
        "btk,kd->btd", out.reshape(b, tq, nq * dh), p["wo"].astype(x.dtype)
    )
    return axes.psum_tp(out)


def _project_enc_kv(p, enc_out, dims):
    b, ts, _ = enc_out.shape
    nkv, dh = dims.layout.kv_local, dims.layout.head_dim
    k = jnp.einsum("btd,dk->btk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dk->btk", enc_out, p["wv"].astype(enc_out.dtype))
    return k.reshape(b, ts, nkv, dh), v.reshape(b, ts, nkv, dh)


def encode(params, frames, axes: Axes, cfg, dtype=jnp.bfloat16):
    """frames: (B, Ts, frontend_dim) -> encoder states (B, Ts, d)."""
    x = jnp.einsum(
        "btf,fd->btd", frames.astype(dtype), params["frontend_proj"].astype(dtype)
    )
    b, ts = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(ts, dtype=jnp.int32), (b, ts))
    dims = resolve_dims(cfg, axes.tp_size, axes.tp_size)

    def body(h, lp):
        def f(hh, pp):
            z = _ln(hh, pp["ln1"])
            bq, tq, _ = z.shape
            nq, nkv, dh = dims.layout.q_local, dims.layout.kv_local, dims.layout.head_dim
            q = jnp.einsum("btd,dk->btk", z, pp["attn"]["wq"].astype(z.dtype))
            k = jnp.einsum("btd,dk->btk", z, pp["attn"]["wk"].astype(z.dtype))
            v = jnp.einsum("btd,dk->btk", z, pp["attn"]["wv"].astype(z.dtype))
            q = rope(q.reshape(bq, tq, nq, dh), positions)
            k = rope(k.reshape(bq, tq, nkv, dh), positions)
            a = _chunked_attn(
                q, k, v.reshape(bq, tq, nkv, dh), positions, positions,
                window=None, chunk=min(1024, tq), causal=False,
            )
            a = jnp.einsum(
                "btk,kd->btd", a.reshape(bq, tq, nq * dh), pp["attn"]["wo"].astype(z.dtype)
            )
            hh = hh + axes.psum_tp(a)
            hh = hh + gelu_mlp(pp["mlp"], _ln(hh, pp["ln2"]), axes)
            return hh

        return jax.checkpoint(f)(h, lp), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["ln_enc"])


def encdec_loss(params, batch, axes: Axes, cfg, dtype=jnp.bfloat16):
    """batch: frames (B,Ts,fd), tokens (B,Tt), labels (B,Tt)."""
    enc_out = encode(params, batch["frames"], axes, cfg, dtype)
    dims = resolve_dims(cfg, axes.tp_size, axes.tp_size)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens, axes).astype(dtype)
    b, tt = x.shape[:2]
    ts = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(tt, dtype=jnp.int32), (b, tt))
    enc_pos = jnp.broadcast_to(jnp.arange(ts, dtype=jnp.int32), (b, ts))

    def body(h, lp):
        def f(hh, pp):
            z = _ln(hh, pp["ln1"])
            hh = hh + attn.attention_train(
                pp["self_attn"], z, positions, axes, dims.layout
            )  # causal self-attention
            kv = _project_enc_kv(pp["cross_attn"], enc_out, dims)
            hh = hh + _cross_attention(
                pp["cross_attn"], _ln(hh, pp["ln_x"]), kv, positions, enc_pos, axes, dims
            )
            hh = hh + gelu_mlp(pp["mlp"], _ln(hh, pp["ln2"]), axes)
            return hh

        return jax.checkpoint(f)(h, lp), None

    x, _ = lax.scan(body, x, params["dec_layers"])
    x = _ln(x, params["ln_dec"])
    logits = jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(x.dtype)
    ).astype(jnp.float32)
    labels = batch["labels"]
    per_tok = tp_cross_entropy(logits, labels, axes)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_encdec_cache(cfg, tp, n_shards, b_local, s_local, s_src, dtype=jnp.bfloat16):
    dims = resolve_dims(cfg, tp, n_shards)
    L = cfg.dec_layers
    nkv, dh = dims.layout.kv_local, dims.layout.head_dim
    stack = lambda t: jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), t)
    self_c = attn.init_cache(b_local, s_local, dims.layout, dtype)
    cross = {
        "k": jnp.zeros((b_local, s_src, nkv, dh), dtype),
        "v": jnp.zeros((b_local, s_src, nkv, dh), dtype),
        "pos": jnp.zeros((b_local, s_src), jnp.int32),
    }
    return {"self": stack(self_c), "cross": stack(cross)}


def encdec_prefill(params, frames, cache, axes: Axes, cfg, dtype=jnp.bfloat16):
    """Run the encoder and fill the cross-attention KV cache."""
    enc_out = encode(params, frames, axes, cfg, dtype)
    dims = resolve_dims(cfg, axes.tp_size, axes.tp_size)
    b, ts = enc_out.shape[:2]

    def per_layer(lp):
        k, v = _project_enc_kv(lp["cross_attn"], enc_out, dims)
        return {
            "k": k.astype(dtype),
            "v": v.astype(dtype),
            "pos": jnp.broadcast_to(jnp.arange(ts, dtype=jnp.int32), (b, ts)),
        }

    cross = jax.vmap(per_layer)(params["dec_layers"])
    return dict(cache, cross=cross)


def encdec_decode_step(params, cache, tokens, pos, axes: Axes, cfg, dtype=jnp.bfloat16):
    dims = resolve_dims(cfg, axes.tp_size, axes.tp_size)
    x = embed_lookup(params["embed"], tokens[:, None], axes).astype(dtype)

    def body(h, xs):
        lp, sc, cc = xs
        a, new_sc = attn.attention_decode(
            lp["self_attn"], _ln(h, lp["ln1"]), pos, sc, axes, dims.layout
        )
        h = h + a
        z = _ln(h, lp["ln_x"])
        b = z.shape[0]
        nq, dh = dims.layout.q_local, dims.layout.head_dim
        nkv = dims.layout.kv_local
        group = nq // nkv
        q = jnp.einsum("btd,dk->btk", z, lp["cross_attn"]["wq"].astype(z.dtype))
        qh = q.reshape(b, nkv, group, dh)
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        logits = (
            jnp.einsum("bhgd,bshd->bhgs", qh.astype(jnp.float32), cc["k"].astype(jnp.float32))
            * scale
        )
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", w, cc["v"].astype(jnp.float32))
        o = o.reshape(b, 1, nq * dh).astype(z.dtype)
        o = jnp.einsum("btk,kd->btd", o, lp["cross_attn"]["wo"].astype(z.dtype))
        h = h + axes.psum_tp(o)
        h = h + gelu_mlp(lp["mlp"], _ln(h, lp["ln2"]), axes)
        return h, new_sc

    x, new_self = lax.scan(body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = _ln(x, params["ln_dec"])
    logits = jnp.einsum(
        "btd,dv->btv", x, params["lm_head"].astype(x.dtype)
    ).astype(jnp.float32)[:, 0]
    return logits, dict(cache, self=new_self)
