"""Mixture-of-Experts with two sharding strategies, auto-selected:

  * ``ep``  (n_experts % tp == 0, e.g. deepseek 64 experts / 16 devices):
    classic expert parallelism — each device owns n_experts/tp experts;
    tokens are dispatched with a capacity-factor buffer and exchanged via
    all_to_all over the model axis, expert FFN runs on the owner, results
    come back via the reverse all_to_all.

  * ``tp``  (n_experts < tp, e.g. mixtral 8 experts / 16 devices): every
    expert's FFN is tensor-sharded over the full model axis; tokens are
    gathered per-expert into capacity buffers locally (no all_to_all) and
    each expert runs as a column→row parallel MLP. Avoids replicated expert
    weights, keeping the "sharded or replicated" parameter invariant.

Both use top-k token-choice routing with probability renormalization and
token dropping at capacity (Switch/Mixtral-style). Shared experts
(DeepSeek-V2) are plain TP MLPs added unconditionally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel import collectives as coll

from repro.models.common import Axes, dense_init, swiglu
from repro.models.mlp import init_swiglu, swiglu_mlp


def pick_strategy(n_experts: int, tp: int) -> str:
    if tp == 1:
        return "tp"
    return "ep" if n_experts % tp == 0 else "tp"


def init_moe_params(
    key,
    d_model,
    d_ff,
    n_experts,
    axes_tp: int,
    *,
    n_shared: int = 0,
    d_ff_shared: int | None = None,
    dtype=jnp.float32,
):
    """Expert weights local shard. strategy=ep: (E_loc, d, d_ff) full d_ff;
    strategy=tp: (E, d, d_ff/tp)."""
    strategy = pick_strategy(n_experts, axes_tp)
    ks = jax.random.split(key, 5)
    if strategy == "ep":
        e_loc, ff_loc = n_experts // axes_tp, d_ff
    else:
        e_loc, ff_loc = n_experts, d_ff // axes_tp
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), d_model, jnp.float32),
        "w_gate": dense_init(ks[1], (e_loc, d_model, ff_loc), d_model, dtype),
        "w_up": dense_init(ks[2], (e_loc, d_model, ff_loc), d_model, dtype),
        "w_down": dense_init(ks[3], (e_loc, ff_loc, d_model), ff_loc, dtype),
    }
    if n_shared:
        ff_sh = (d_ff_shared or d_ff * n_shared) // axes_tp
        p["shared"] = init_swiglu(ks[4], d_model, ff_sh, dtype)
    return p


def _route(router_w, x, n_experts, top_k):
    """x: (N, d) -> (weights (N, k), ids (N, k)) with renormalized probs."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids


def _dispatch_indices(ids, w, n_experts, capacity):
    """Compute per-(token,choice) target slot within its expert's capacity
    buffer; over-capacity tokens are dropped (weight zeroed)."""
    n, k = ids.shape
    flat_e = ids.reshape(-1)  # (N*k,)
    # position of each (token,choice) within its expert's arrival order
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (N*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    slot = jnp.sum(pos_in_e * onehot, axis=-1)  # (N*k,)
    keep = slot < capacity
    return flat_e, jnp.where(keep, slot, capacity - 1), keep


def moe_tp(params, x, axes: Axes, *, n_experts, top_k, capacity_factor=1.25):
    """TP-strategy MoE. x: (B, T, d) replicated across TP."""
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    w, ids = _route(params["router"], xf, n_experts, top_k)
    capacity = max(8, int(n * top_k * capacity_factor / n_experts))
    flat_e, slot, keep = _dispatch_indices(ids, w, n_experts, capacity)
    # scatter tokens into (E, C, d) buffers
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    src = jnp.repeat(xf, top_k, axis=0)  # (N*k, d) token per choice
    buf = buf.at[flat_e, slot].add(jnp.where(keep[:, None], src, 0))
    # per-expert column->row parallel SwiGLU (batched over experts)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = swiglu(g, u)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    out_buf = axes.psum_tp(out_buf)
    # gather back with routing weights
    picked = out_buf[flat_e, slot]  # (N*k, d)
    wk = (w.reshape(-1) * keep).astype(x.dtype)
    out = jnp.sum((picked * wk[:, None]).reshape(n, top_k, d), axis=1)
    out = out.reshape(b, t, d)
    if "shared" in params:
        out = out + swiglu_mlp(params["shared"], x, axes)
    return out


def moe_ep(params, x, axes: Axes, *, n_experts, top_k, capacity_factor=1.25):
    """EP-strategy MoE: experts sharded over the model axis; token exchange
    via all_to_all. x: (B, T, d) replicated across TP (each TP member handles
    an equal slice of local tokens to avoid duplicate compute)."""
    tp = axes.tp_size
    b, t, d = x.shape
    n_all = b * t
    xf = x.reshape(n_all, d)
    # each TP member routes its 1/tp slice of the tokens
    if axes.tp:
        n = n_all // tp
        start = axes.tp_index() * n
        xf = lax.dynamic_slice_in_dim(xf, start, n, axis=0)
    else:
        n = n_all
    w, ids = _route(params["router"], xf, n_experts, top_k)
    e_loc = n_experts // tp
    # capacity per (device, expert) buffer
    capacity = max(8, int(n * top_k * capacity_factor / n_experts))
    flat_e, slot, keep = _dispatch_indices(ids, w, n_experts, capacity)
    # dispatch buffer grouped by owner device: (tp, e_loc, C, d)
    buf = jnp.zeros((tp, e_loc, capacity, d), x.dtype)
    owner = flat_e // e_loc
    sub = flat_e % e_loc
    src = jnp.repeat(xf, top_k, axis=0)
    buf = buf.at[owner, sub, slot].add(jnp.where(keep[:, None], src, 0))
    if axes.tp:
        # exchange: device i sends buf[j] to device j -> receives (tp, e_loc, C, d)
        buf = coll.all_to_all(buf, axes.tp, split_axis=0, concat_axis=0, tiled=True)
        buf = buf.reshape(tp, e_loc, capacity, d)
    # expert FFN on owned experts over all received tokens: fold sender dim
    recv = buf.transpose(1, 0, 2, 3).reshape(e_loc, tp * capacity, d)
    g = jnp.einsum("ecd,edf->ecf", recv, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", recv, params["w_up"].astype(x.dtype))
    h = swiglu(g, u)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    out_buf = out_buf.reshape(e_loc, tp, capacity, d).transpose(1, 0, 2, 3)
    if axes.tp:
        out_buf = coll.all_to_all(out_buf, axes.tp, split_axis=0, concat_axis=0, tiled=True)
        out_buf = out_buf.reshape(tp, e_loc, capacity, d)
    picked = out_buf[owner, sub, slot]
    wk = (w.reshape(-1) * keep).astype(x.dtype)
    out = jnp.sum((picked * wk[:, None]).reshape(n, top_k, d), axis=1)
    if axes.tp:
        # re-assemble the full token set across TP members
        full = jnp.zeros((n_all, d), x.dtype)
        full = lax.dynamic_update_slice_in_dim(full, out, axes.tp_index() * n, axis=0)
        out = axes.psum_tp(full)
    out = out.reshape(b, t, d)
    if "shared" in params:
        out = out + swiglu_mlp(params["shared"], x, axes)
    return out


def moe_block(params, x, axes: Axes, *, n_experts, top_k, capacity_factor=1.25):
    strategy = pick_strategy(n_experts, axes.tp_size)
    fn = moe_ep if strategy == "ep" else moe_tp
    return fn(
        params, x, axes, n_experts=n_experts, top_k=top_k, capacity_factor=capacity_factor
    )
