"""Mamba2 (SSD) block — chunked parallel scan for training, O(1)-state decode.

State space:  h_t = exp(A·dt_t) h_{t-1} + dt_t · (B_t ⊗ x_t),   y_t = C_t · h_t
with scalar A<0 per head, shared B/C projections (ngroups=1), per-head dt.

Training uses the SSD chunked algorithm: intra-chunk quadratic form +
inter-chunk state recurrence (lax.scan over chunks) — sub-quadratic in T and
the reason the zamba2/xlstm configs are the ones allowed to run long_500k.

TP: heads (d_inner) are sharded over the model axis; B/C projections are
replicated; out-proj is row-parallel (+psum). Decode carries a causal-conv
tail buffer and the (N×P) state per head.

Simplifications vs the reference CUDA implementation (documented per
DESIGN.md hardware-adaptation): the depthwise conv is applied to x only (not
B/C), and gating norm is RMS per head. Neither changes the compute/memory
shape of the block.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import Axes, dense_init, rmsnorm

CONV_K = 4


def init_mamba2_params(
    key, d_model, n_heads_local, head_dim, d_state, dtype=jnp.float32
):
    d_inner_loc = n_heads_local * head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_xz": dense_init(ks[0], (d_model, 2 * d_inner_loc), d_model, dtype),
        "w_bc": dense_init(ks[1], (d_model, 2 * d_state), d_model, dtype),
        "w_dt": dense_init(ks[2], (d_model, n_heads_local), d_model, dtype),
        "dt_bias": jnp.full((n_heads_local,), -4.0, dtype),  # softplus -> small dt
        "conv_w": dense_init(ks[3], (CONV_K, d_inner_loc), CONV_K, dtype),
        "a_log": jnp.zeros((n_heads_local,), dtype),  # A = -exp(a_log)
        "d_skip": jnp.ones((n_heads_local,), dtype),
        "norm_w": jnp.ones((d_inner_loc,), dtype),
        "w_out": dense_init(ks[4], (d_inner_loc, d_model), d_inner_loc, dtype),
    }


def _causal_conv(x, w):
    """x: (B, T, C); w: (K, C) depthwise."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunk(h_in, xs):
    """One chunk. h_in: (B,H,N,P). xs: x (B,Q,H,P), dt (B,Q,H), bc (B,Q,2N),
    a (H,). Returns (h_out, y (B,Q,H,P))."""
    x, dt, bc, a = xs
    n = bc.shape[-1] // 2
    bmat, cmat = bc[..., :n], bc[..., n:]
    loga = a[None, None, :] * dt  # (B,Q,H) log decay per step (a<0)
    s = jnp.cumsum(loga, axis=1)  # (B,Q,H) cumulative log decay
    # intra-chunk: M[t,τ] = (C_t·B_τ) exp(s_t - s_τ) dt_τ, τ<=t
    cb = jnp.einsum("btn,bsn->bts", cmat, bmat)  # (B,Q,Q)
    decay = jnp.exp(
        jnp.clip(s[:, :, None, :] - s[:, None, :, :], -60.0, 0.0)
    )  # (B,Q,Q,H)
    q = x.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    m = cb[..., None] * decay * dt[:, None, :, :]
    m = jnp.where(causal[None, :, :, None], m, 0.0)
    y_intra = jnp.einsum("btsh,bshp->bthp", m, x)
    # inter-chunk: y += exp(s_t) C_t h_in
    y_inter = jnp.exp(s)[..., None] * jnp.einsum(
        "btn,bhnp->bthp", cmat, h_in
    )
    # state update: h_out = exp(s_Q) h_in + Σ_τ exp(s_Q-s_τ) dt_τ B_τ⊗x_τ
    w_last = jnp.exp(jnp.clip(s[:, -1:, :] - s, -60.0, 0.0)) * dt  # (B,Q,H)
    dh = jnp.einsum("bqh,bqn,bqhp->bhnp", w_last, bmat, x)
    h_out = jnp.exp(s[:, -1, :])[:, :, None, None] * h_in + dh
    return h_out, y_intra + y_inter


def mamba2_train(
    params, x, axes: Axes, *, n_heads_local, head_dim, d_state, chunk=256
):
    """x: (B, T, d) replicated. Returns (B, T, d)."""
    b, t, _ = x.shape
    h_loc, p_dim, n = n_heads_local, head_dim, d_state
    xz = jnp.einsum("btd,dk->btk", x, params["w_xz"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = _causal_conv(xin, params["conv_w"].astype(x.dtype))
    bc = jnp.einsum("btd,dk->btk", x, params["w_bc"].astype(x.dtype)).astype(
        jnp.float32
    )
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(b, t, h_loc, p_dim).astype(jnp.float32)
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nch = t // q
    xs = (
        xh.reshape(b, nch, q, h_loc, p_dim).transpose(1, 0, 2, 3, 4),
        dt.reshape(b, nch, q, h_loc).transpose(1, 0, 2, 3),
        bc.reshape(b, nch, q, 2 * n).transpose(1, 0, 2, 3),
    )
    h0 = jnp.zeros((b, h_loc, n, p_dim), jnp.float32)
    step = jax.checkpoint(lambda h, s: _ssd_chunk(h, (s[0], s[1], s[2], a)))
    _, ys = lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h_loc, p_dim)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, t, h_loc * p_dim).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out)


def init_mamba2_cache(b_local, n_heads_local, head_dim, d_state, dtype=jnp.float32):
    d_inner_loc = n_heads_local * head_dim
    return {
        "conv": jnp.zeros((b_local, CONV_K - 1, d_inner_loc), dtype),
        "h": jnp.zeros((b_local, n_heads_local, d_state, head_dim), jnp.float32),
    }


def mamba2_decode(params, x, cache, axes: Axes, *, n_heads_local, head_dim, d_state):
    """One-token step. x: (B, 1, d)."""
    b = x.shape[0]
    h_loc, p_dim, n = n_heads_local, head_dim, d_state
    xz = jnp.einsum("btd,dk->btk", x, params["w_xz"].astype(x.dtype))
    xin, z = jnp.split(xz[:, 0], 2, axis=-1)  # (B, d_inner_loc)
    # conv over the tail buffer
    hist = jnp.concatenate([cache["conv"], xin[:, None, :]], axis=1)  # (B,K,ch)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.sum(hist * w[None, :, :], axis=1)
    xin_c = jax.nn.silu(conv_out.astype(jnp.float32))
    new_conv = hist[:, 1:, :]
    bc = jnp.einsum("bd,dk->bk", x[:, 0], params["w_bc"].astype(x.dtype)).astype(
        jnp.float32
    )
    bmat, cmat = bc[:, :n], bc[:, n:]
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", x[:, 0], params["w_dt"].astype(x.dtype)).astype(
            jnp.float32
        )
        + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin_c.reshape(b, h_loc, p_dim)
    decay = jnp.exp(a[None, :] * dt)  # (B,H)
    h_new = decay[:, :, None, None] * cache["h"] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bmat, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat, h_new)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, h_loc * p_dim).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    y = rmsnorm(y, params["norm_w"])
    out = jnp.einsum("btk,kd->btd", y, params["w_out"].astype(x.dtype))
    return axes.psum_tp(out), dict(cache, conv=new_conv, h=h_new)
