"""Shared model machinery: TP-aware primitives usable both on a single
device (Axes(tp=None), smoke tests) and inside shard_map on the production
mesh (explicit psum over the `model` axis).

Sharding convention (Megatron-style):
  * embeddings: vocab dim sharded over TP; lookup masks out-of-slice ids and
    psums partial rows;
  * attention QKV: column-parallel (heads sharded); out-proj: row-parallel
    (+psum);
  * MLP in: column-parallel; MLP out: row-parallel (+psum);
  * norms / scalars: replicated;
  * logits: column-parallel (vocab sharded) + the Megatron parallel CE that
    never materializes gathered logits.

Head padding: when num_heads % tp != 0 we pad Q heads (zero-out-proj rows →
mathematically a no-op) and pad KV heads to the TP size as independent heads,
keeping every parameter either fully sharded or fully replicated over the
model axis (required so gradient aggregation semantics stay uniform). See
DESIGN.md §Hardware adaptation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel import collectives as coll


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Axes:
    """Names of mesh axes visible inside the step function. All static."""

    tp: Optional[str] = dataclasses.field(default=None, metadata=dict(static=True))
    tp_size: int = dataclasses.field(default=1, metadata=dict(static=True))
    # sequence-parallel axes for long-context decode (KV shards); the data
    # axes re-purposed when batch==1. Tuple because multi-pod re-uses
    # ("pod","data") jointly.
    sp: tuple = dataclasses.field(default=(), metadata=dict(static=True))
    sp_sizes: tuple = dataclasses.field(default=(), metadata=dict(static=True))

    @property
    def sp_size(self) -> int:
        out = 1
        for s in self.sp_sizes:
            out *= s
        return out

    def tp_index(self):
        return lax.axis_index(self.tp) if self.tp else jnp.zeros((), jnp.int32)

    def sp_index(self):
        idx = jnp.zeros((), jnp.int32)
        for ax, size in zip(self.sp, self.sp_sizes):
            idx = idx * size + lax.axis_index(ax)
        return idx

    def psum_tp(self, x):
        if not self.tp:
            return x
        # named so a remat policy can SAVE collective outputs instead of
        # re-running them in the backward pass (§Perf "save_psum" policy)
        from jax.ad_checkpoint import checkpoint_name

        return checkpoint_name(coll.psum(x, self.tp), "tp_psum")

    def pmax_tp(self, x):
        return coll.pmax(x, self.tp) if self.tp else x


SINGLE = Axes()


def pad_to_multiple(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    """Resolved (padded) head counts for a TP degree."""

    n_q: int  # padded global Q heads
    n_kv: int  # padded global KV heads
    head_dim: int
    q_local: int
    kv_local: int

    @property
    def group(self) -> int:
        return self.n_q // self.n_kv


def plan_heads(n_q: int, n_kv: int, head_dim: int, tp: int) -> HeadLayout:
    q_pad = pad_to_multiple(n_q, tp)
    kv_pad = n_kv if n_kv % tp == 0 or tp % 1 != 0 else n_kv
    if kv_pad % tp != 0 and tp % kv_pad == 0:
        kv_pad = tp  # pad KV heads up to one per device
    elif kv_pad % tp != 0:
        kv_pad = pad_to_multiple(n_kv, tp)
    # ensure group divides evenly
    if q_pad % kv_pad != 0:
        q_pad = pad_to_multiple(q_pad, kv_pad)
        q_pad = pad_to_multiple(q_pad, tp)
    return HeadLayout(q_pad, kv_pad, head_dim, q_pad // tp, kv_pad // tp)


# --------------------------------------------------------------------------
# initializers (local-shard aware: callers pass the LOCAL shape)
# --------------------------------------------------------------------------
def dense_init(key, shape, in_dim, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(in_dim, 1))
    return jax.random.uniform(key, shape, dtype, -scale, scale)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope(x, positions, theta: float = 10000.0):
    """x: (..., T, H, dh); positions: (..., T) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# TP embedding lookup + parallel cross entropy
# --------------------------------------------------------------------------
def embed_lookup(table_local, ids, axes: Axes):
    """table_local: (V/tp, d); ids: (...) int32 global vocab ids."""
    v_local = table_local.shape[0]
    start = axes.tp_index() * v_local
    local_ids = ids - start
    ok = (local_ids >= 0) & (local_ids < v_local)
    rows = jnp.take(table_local, jnp.clip(local_ids, 0, v_local - 1), axis=0)
    rows = jnp.where(ok[..., None], rows, 0)
    return axes.psum_tp(rows)


def tp_cross_entropy(logits_local, labels, axes: Axes):
    """Megatron parallel softmax CE. logits_local: (..., V/tp) f32;
    labels: (...) global ids. Returns per-token loss (...)."""
    v_local = logits_local.shape[-1]
    start = axes.tp_index() * v_local
    logits_local = logits_local.astype(jnp.float32)
    # stabilizer only — not a differentiable path (pmax has no JVP rule)
    local_max = jax.lax.stop_gradient(jnp.max(logits_local, axis=-1))
    gmax = axes.pmax_tp(local_max)
    shifted = logits_local - gmax[..., None]
    sumexp = axes.psum_tp(jnp.sum(jnp.exp(shifted), axis=-1))
    local_labels = labels - start
    ok = (local_labels >= 0) & (local_labels < v_local)
    picked = jnp.take_along_axis(
        shifted, jnp.clip(local_labels, 0, v_local - 1)[..., None], axis=-1
    )[..., 0]
    picked = axes.psum_tp(jnp.where(ok, picked, 0.0))
    return jnp.log(sumexp) - picked


# --------------------------------------------------------------------------
# parallel dense helpers (inside shard_map the weights are already local)
# --------------------------------------------------------------------------
def col_parallel(x, w, axes: Axes, b=None):
    """x: (..., d_in) replicated; w: (d_in, d_out/tp) local. Out: sharded."""
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def row_parallel(x, w, axes: Axes, b=None):
    """x: (..., d_in/tp) sharded; w: (d_in/tp, d_out) local. Out: replicated."""
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    y = axes.psum_tp(y)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
