from repro.models.common import Axes
