"""Generic jaxpr walking — THE shared iteration layer for every structural
pass over a built step (cost model, overlap counters, the wire auditor).

Promoted out of ``benchmarks/jaxpr_cost.py`` (PR 8) so src-side analyses
don't import a benchmark module: the benchmarks now re-export from here.
Everything in this module is structural only — no cost semantics, no rule
semantics; those live in the consumers (:mod:`benchmarks.jaxpr_cost`,
:mod:`repro.analysis.wire_audit`, :mod:`repro.analysis.schedule`,
:mod:`repro.analysis.traffic`).

The cross-scope dataflow graph (PR 9, promoted from ``wire_audit`` where it
served only the observed-clip rule) also lives here: ``build_graph`` records
per-var defining eqns, consuming eqns AND equality links across scope
boundaries (call in/outvars, scan consts/carries/xs/ys, cond branches, while
carries), so both backward reachability (what feeds a value) and forward
reachability (what a value feeds) are one traversal each — the primitives the
schedule analyzer's overlap-eligibility classification is built from.

Fixes folded in with the promotion (both were latent walker bugs):

  * ``COLLECTIVES`` includes ``pmean`` — a backend/JAX version that emits a
    first-class pmean primitive would previously count zero collective bytes
    in the roofline table (current CPU JAX lowers ``lax.pmean`` to
    psum+div, so the entry is future-proofing, not a behavior change here);
  * ``iter_eqns`` scans the REMAINING params of a ``cond`` eqn after its
    branches instead of ``continue``-ing — a cond carrying another sub-jaxpr
    param would previously have that subtree silently skipped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

__all__ = [
    "COLLECTIVES",
    "CALL_PRIMS",
    "iter_eqns",
    "iter_eqns_scaled",
    "eqn_subjaxprs",
    "eqn_axes",
    "collective_eqns",
    "aval_size_bytes",
    "aval_nelem",
    "DataflowGraph",
    "build_graph",
    "backward_eqns",
    "forward_eqns",
]

# collective primitive name -> communication kind. The auditor and the cost
# model both key off this table; a primitive missing here is invisible to
# every structural pass, so additions belong HERE, not in the consumers.
COLLECTIVES = {
    "psum": "all-reduce",
    "pmean": "all-reduce",  # only present on JAX builds with a pmean prim
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "psum_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
}

# collectives whose payload is combined across devices (vs merely moved /
# concatenated) — the surface the floatless-wire rule audits. A ppermute hop
# is included: on the ring route it carries in-flight partial SUMS.
REDUCING_COLLECTIVES = frozenset(
    {"psum", "pmean", "pmax", "pmin", "reduce_scatter", "psum_scatter",
     "ppermute"}
)

CALL_PRIMS = ("pjit", "closed_call", "core_call", "custom_jvp_call",
              "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "remat2",
              "checkpoint", "custom_lin")


def _as_jaxpr(v):
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return v.jaxpr if hasattr(v, "jaxpr") else v


def eqn_subjaxprs(eqn) -> Iterator:
    """Every sub-jaxpr held by ``eqn.params``, each exactly once.

    Scans ALL params: the ``branches`` tuple of a cond AND any ``*jaxpr``
    param the same eqn carries (the old walker ``continue``-d after the
    branches, skipping sibling sub-jaxpr params)."""
    for k, v in eqn.params.items():
        if k == "branches":
            for b in v:
                yield _as_jaxpr(b)
        elif k.endswith("jaxpr") and (hasattr(v, "eqns") or hasattr(v, "jaxpr")):
            yield _as_jaxpr(v)


def iter_eqns(jaxpr) -> Iterator:
    """Yield every eqn in `jaxpr` and all sub-jaxprs, each ONCE — cond
    branches and while cond/body included, scan bodies NOT multiplied by
    trip count. Structural-counting walks (collective counts, primitive
    presence, the wire audit) build on this; :func:`benchmarks.jaxpr_cost
    .jaxpr_cost` keeps its own recursion because byte/FLOP accounting needs
    scan-length scaling and worst-cond-branch semantics that a flat
    iteration cannot express."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in eqn_subjaxprs(eqn):
            yield from iter_eqns(sub)


def eqn_axes(eqn) -> Tuple[str, ...]:
    """The mesh/vmap axis names a collective eqn communicates over."""
    p = eqn.params
    for k in ("axes", "axis_name", "axis_names"):
        if k in p:
            a = p[k]
            if isinstance(a, (tuple, list, frozenset, set)):
                return tuple(sorted(str(x) for x in a))
            return (str(a),)
    return ("?",)


def collective_eqns(jaxpr) -> Iterator[tuple]:
    """Yield ``(eqn, kind, axes)`` for every collective in the whole tree."""
    for eqn in iter_eqns(jaxpr):
        kind = COLLECTIVES.get(eqn.primitive.name)
        if kind is not None:
            yield eqn, kind, eqn_axes(eqn)


def iter_eqns_scaled(jaxpr, scale: int = 1) -> Iterator[Tuple[object, int]]:
    """Yield ``(eqn, multiplicity)`` over the whole tree: scan bodies are
    multiplied by their trip count (nested scans compound), while bodies
    count once (no unbounded whiles in this codebase), cond branches each
    count once (branch selection is dynamic; a structural pass sees both).
    The flat-count sibling of :func:`iter_eqns` for passes that need
    execution multiplicity (FLOP totals, scan-aware byte accounting)."""
    for eqn in jaxpr.eqns:
        yield eqn, scale
        name = eqn.primitive.name
        k = int(eqn.params["length"]) if name == "scan" else 1
        for sub in eqn_subjaxprs(eqn):
            yield from iter_eqns_scaled(sub, scale * k)


def aval_size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def aval_nelem(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


# ---------------------------------------------------------------------------
# cross-scope dataflow graph (promoted from wire_audit in PR 9)
# ---------------------------------------------------------------------------
def is_var(a) -> bool:
    """True for jaxpr Vars (Literals carry a ``val``)."""
    return not hasattr(a, "val")


@dataclasses.dataclass
class DataflowGraph:
    """Value-flow indices over one closed jaxpr and every nested scope.

    ``defs``:  id(var) -> defining eqn;
    ``uses``:  id(var) -> eqns consuming it (within its own scope);
    ``links``: id(var) -> vars EQUAL to it across a scope boundary (call
    in/outvars, scan consts/carries/xs/ys, cond branches, while carries);
    ``opaque``: id(call eqn) -> ids of every eqn inside its sub-jaxprs, for
    call eqns whose body links were withheld (see ``shared_bodies`` below).

    Links are value-equality edges, so reachability may traverse them in
    either direction — that is what lets one backward or forward sweep cross
    shard_map / pjit / scan bodies without modeling each call convention."""

    defs: Dict[int, object]
    uses: Dict[int, List[object]]
    links: Dict[int, List[object]]
    opaque: Dict[int, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict
    )


def _count_call_sites(jaxpr, counts: Dict[int, int]) -> None:
    for eqn in jaxpr.eqns:
        for sub in eqn_subjaxprs(eqn):
            counts[id(sub)] = counts.get(id(sub), 0) + 1
            _count_call_sites(sub, counts)


def build_graph(closed_jaxpr, *, shared_bodies: str = "link") -> DataflowGraph:
    """Build the :class:`DataflowGraph` for a (Closed)Jaxpr.

    ``shared_bodies`` decides what to do with a sub-jaxpr OBJECT that is
    shared by several call sites (jax caches jaxprs, so e.g. one tiny
    ``pjit(clip)`` body serves every microbatch's call):

      * ``"link"`` (default): link body vars to EVERY call site. Boundary
        links become a hub joining all call sites, so reachability is merged
        across them — maximally conservative, fine for existence checks
        (wire_audit's observed-clip rule wants "is SOME clamp upstream").
      * ``"opaque"``: withhold boundary links for multi-site bodies and
        record the call eqn in ``opaque`` instead; reachability walks treat
        the call atomically (its inputs feed all its outputs) and
        wholesale-include the body's eqns when the call itself is reached.
        Per-call-site precise — required by the schedule analyzer, where the
        "link" hub would order every microbatch against every collective and
        falsely kill all concurrency.
    """
    if shared_bodies not in ("link", "opaque"):
        raise ValueError(
            f"shared_bodies must be 'link' or 'opaque', got {shared_bodies!r}"
        )
    g = DataflowGraph(defs={}, uses={}, links={}, opaque={})
    site_counts: Dict[int, int] = {}
    if shared_bodies == "opaque":
        _count_call_sites(_as_jaxpr(closed_jaxpr), site_counts)

    def link(a, b):
        if is_var(a) and is_var(b):
            g.links.setdefault(id(a), []).append(b)
            g.links.setdefault(id(b), []).append(a)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for ov in eqn.outvars:
                g.defs[id(ov)] = eqn
            for iv_ in eqn.invars:
                if is_var(iv_):
                    g.uses.setdefault(id(iv_), []).append(eqn)
            name = eqn.primitive.name
            p = eqn.params
            if any(site_counts.get(id(sub), 0) > 1
                   for sub in eqn_subjaxprs(eqn)):
                g.opaque[id(eqn)] = tuple(
                    id(e)
                    for sub in eqn_subjaxprs(eqn)
                    for e in iter_eqns(sub)
                )
                for sub in eqn_subjaxprs(eqn):
                    walk(sub)
                continue
            if name == "scan":
                body = _as_jaxpr(p["jaxpr"])
                nc, nk = p["num_consts"], p["num_carry"]
                for i in range(nc):
                    link(body.invars[i], eqn.invars[i])
                for j in range(nk):
                    link(body.invars[nc + j], eqn.invars[nc + j])  # init
                    link(body.invars[nc + j], body.outvars[j])  # loop
                    link(eqn.outvars[j], body.outvars[j])
                for k in range(nc + nk, len(body.invars)):
                    link(body.invars[k], eqn.invars[k])
                for j in range(nk, len(body.outvars)):
                    link(eqn.outvars[j], body.outvars[j])
            elif name == "while":
                body = p["body_jaxpr"].jaxpr
                cn, bn = p["cond_nconsts"], p["body_nconsts"]
                carry = eqn.invars[cn + bn:]
                for i in range(bn):
                    link(body.invars[i], eqn.invars[cn + i])
                for j, c in enumerate(carry):
                    link(body.invars[bn + j], c)
                    link(body.invars[bn + j], body.outvars[j])
                    link(eqn.outvars[j], body.outvars[j])
            elif name == "cond":
                for br in p["branches"]:
                    sub = _as_jaxpr(br)
                    for bi, xi in zip(sub.invars, eqn.invars[1:]):
                        link(bi, xi)
                    for bo, xo in zip(sub.outvars, eqn.outvars):
                        link(xo, bo)
            else:
                for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                    if k in p:
                        sub = _as_jaxpr(p[k])
                        if (len(sub.invars) == len(eqn.invars)
                                and len(sub.outvars) == len(eqn.outvars)):
                            for bi, xi in zip(sub.invars, eqn.invars):
                                link(bi, xi)
                            for bo, xo in zip(sub.outvars, eqn.outvars):
                                link(xo, bo)
                        break
            for sub in eqn_subjaxprs(eqn):
                walk(sub)

    walk(_as_jaxpr(closed_jaxpr))
    return g


def backward_eqns(roots, graph: DataflowGraph) -> set:
    """ids of every eqn whose output can flow into any root var. Reaching an
    ``opaque`` call eqn wholesale-includes its body's eqns (everything inside
    executes before the call's outputs exist)."""
    seen_vars: set = set()
    hit: set = set()
    stack = [r for r in roots if is_var(r)]
    while stack:
        v = stack.pop()
        if id(v) in seen_vars:
            continue
        seen_vars.add(id(v))
        eqn = graph.defs.get(id(v))
        if eqn is not None and id(eqn) not in hit:
            hit.add(id(eqn))
            hit.update(graph.opaque.get(id(eqn), ()))
            stack.extend(a for a in eqn.invars if is_var(a))
        stack.extend(graph.links.get(id(v), ()))
    return hit


def forward_eqns(roots, graph: DataflowGraph) -> set:
    """ids of every eqn any root var can flow into (the consumer closure —
    the dual of :func:`backward_eqns`, via ``uses`` instead of ``defs``).
    Reaching an ``opaque`` call eqn wholesale-includes its body's eqns
    (everything inside executes after the call's inputs arrive)."""
    seen_vars: set = set()
    hit: set = set()
    stack = [r for r in roots if is_var(r)]
    while stack:
        v = stack.pop()
        if id(v) in seen_vars:
            continue
        seen_vars.add(id(v))
        for eqn in graph.uses.get(id(v), ()):
            if id(eqn) not in hit:
                hit.add(id(eqn))
                hit.update(graph.opaque.get(id(eqn), ()))
                stack.extend(ov for ov in eqn.outvars if is_var(ov))
        stack.extend(graph.links.get(id(v), ()))
    return hit
