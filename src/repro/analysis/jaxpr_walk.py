"""Generic jaxpr walking — THE shared iteration layer for every structural
pass over a built step (cost model, overlap counters, the wire auditor).

Promoted out of ``benchmarks/jaxpr_cost.py`` (PR 8) so src-side analyses
don't import a benchmark module: the benchmarks now re-export from here.
Everything in this module is structural only — no cost semantics, no rule
semantics; those live in the consumers (:mod:`benchmarks.jaxpr_cost`,
:mod:`repro.analysis.wire_audit`).

Fixes folded in with the promotion (both were latent walker bugs):

  * ``COLLECTIVES`` includes ``pmean`` — a backend/JAX version that emits a
    first-class pmean primitive would previously count zero collective bytes
    in the roofline table (current CPU JAX lowers ``lax.pmean`` to
    psum+div, so the entry is future-proofing, not a behavior change here);
  * ``iter_eqns`` scans the REMAINING params of a ``cond`` eqn after its
    branches instead of ``continue``-ing — a cond carrying another sub-jaxpr
    param would previously have that subtree silently skipped.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

__all__ = [
    "COLLECTIVES",
    "CALL_PRIMS",
    "iter_eqns",
    "eqn_subjaxprs",
    "eqn_axes",
    "collective_eqns",
    "aval_size_bytes",
    "aval_nelem",
]

# collective primitive name -> communication kind. The auditor and the cost
# model both key off this table; a primitive missing here is invisible to
# every structural pass, so additions belong HERE, not in the consumers.
COLLECTIVES = {
    "psum": "all-reduce",
    "pmean": "all-reduce",  # only present on JAX builds with a pmean prim
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "psum_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
}

# collectives whose payload is combined across devices (vs merely moved /
# concatenated) — the surface the floatless-wire rule audits. A ppermute hop
# is included: on the ring route it carries in-flight partial SUMS.
REDUCING_COLLECTIVES = frozenset(
    {"psum", "pmean", "pmax", "pmin", "reduce_scatter", "psum_scatter",
     "ppermute"}
)

CALL_PRIMS = ("pjit", "closed_call", "core_call", "custom_jvp_call",
              "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "remat2",
              "checkpoint", "custom_lin")


def _as_jaxpr(v):
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return v.jaxpr if hasattr(v, "jaxpr") else v


def eqn_subjaxprs(eqn) -> Iterator:
    """Every sub-jaxpr held by ``eqn.params``, each exactly once.

    Scans ALL params: the ``branches`` tuple of a cond AND any ``*jaxpr``
    param the same eqn carries (the old walker ``continue``-d after the
    branches, skipping sibling sub-jaxpr params)."""
    for k, v in eqn.params.items():
        if k == "branches":
            for b in v:
                yield _as_jaxpr(b)
        elif k.endswith("jaxpr") and (hasattr(v, "eqns") or hasattr(v, "jaxpr")):
            yield _as_jaxpr(v)


def iter_eqns(jaxpr) -> Iterator:
    """Yield every eqn in `jaxpr` and all sub-jaxprs, each ONCE — cond
    branches and while cond/body included, scan bodies NOT multiplied by
    trip count. Structural-counting walks (collective counts, primitive
    presence, the wire audit) build on this; :func:`benchmarks.jaxpr_cost
    .jaxpr_cost` keeps its own recursion because byte/FLOP accounting needs
    scan-length scaling and worst-cond-branch semantics that a flat
    iteration cannot express."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in eqn_subjaxprs(eqn):
            yield from iter_eqns(sub)


def eqn_axes(eqn) -> Tuple[str, ...]:
    """The mesh/vmap axis names a collective eqn communicates over."""
    p = eqn.params
    for k in ("axes", "axis_name", "axis_names"):
        if k in p:
            a = p[k]
            if isinstance(a, (tuple, list, frozenset, set)):
                return tuple(sorted(str(x) for x in a))
            return (str(a),)
    return ("?",)


def collective_eqns(jaxpr) -> Iterator[tuple]:
    """Yield ``(eqn, kind, axes)`` for every collective in the whole tree."""
    for eqn in iter_eqns(jaxpr):
        kind = COLLECTIVES.get(eqn.primitive.name)
        if kind is not None:
            yield eqn, kind, eqn_axes(eqn)


def aval_size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def aval_nelem(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0
