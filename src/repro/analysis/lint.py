"""AST contract linter — the repo's architectural contracts as checkable
rules (C = contract; no jax import anywhere in this module, so it runs
before a test session ever pays the jax startup cost).

Rules:

  C001  single resolution point — the raw JAX collective surface
        (``shard_map`` and the ``lax`` communication collectives: psum,
        pmean, pmax, pmin, ppermute, all_gather, all_to_all, psum_scatter,
        reduce_scatter) may be touched ONLY by
        ``src/repro/parallel/collectives.py``. Everything else goes through
        that shim (or ``CommCtx``), which is what keeps the repo portable
        across JAX API drift and gives the wire auditor one place to tag
        dp-axis semantics. Generalizes
        tests/test_collectives.py::test_single_resolution_point from the
        shard_map API to the whole collective surface.
  C002  optimizer contract — every ``Optimizer(...)`` construction passes
        ``dx_scale`` AND ``fused_kernel`` explicitly. Each was silently
        defaulted once (the §4.1 momentum rescale in PR 1, the fused
        capability flag in PR 4); an explicit kwarg makes a new optimizer
        declare its answer instead of inheriting one.
  C003  codec locality — every ``WireFormat`` subclass lives under
        ``src/repro/wire/``: the codec registry, the psum-safety tests and
        the auditor's chain proof all enumerate that package.

Suppression: end the offending line (or the line above it) with

    # lint: allow(C001) -- <justification>

A non-empty justification is REQUIRED; a bare allow is itself a violation.

CLI: ``python -m repro.analysis.lint src/ [more paths]`` — prints
violations, exits non-zero if any.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

__all__ = ["LINT_RULES", "LintViolation", "lint_source", "lint_paths", "main"]

BANNED_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "ppermute", "all_gather",
    "all_to_all", "psum_scatter", "reduce_scatter", "shard_map",
})

# module paths whose attributes are the raw surface
_RAW_MODULES = ("jax.lax", "jax", "jax.experimental.shard_map",
                "jax.experimental")

_SHIM = "parallel/collectives.py"

LINT_RULES = {
    "C001": "raw shard_map/lax collectives only in parallel/collectives.py",
    "C002": "Optimizer(...) must pass dx_scale and fused_kernel explicitly",
    "C003": "WireFormat subclasses must live under src/repro/wire/",
}

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\((?P<rules>[A-Z0-9,\s]+)\)\s*(?:--\s*(?P<why>.*\S))?"
)


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _allowances(lines: Sequence[str]) -> Dict[int, Dict[str, Optional[str]]]:
    """1-based line -> {rule: justification|None}; an allow comment covers
    its own line and the line below it."""
    out: Dict[int, Dict[str, Optional[str]]] = {}
    for i, text in enumerate(lines, start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = [r.strip() for r in m.group("rules").split(",") if r.strip()]
        why = m.group("why")
        for ln in (i, i + 1):
            d = out.setdefault(ln, {})
            for r in rules:
                d[r] = why
    return out


class _Imports(ast.NodeVisitor):
    """name in this module -> the dotted jax path it denotes (if any)."""

    def __init__(self):
        self.names: Dict[str, str] = {}

    def visit_Import(self, node):
        for a in node.names:
            self.names[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0]
            )

    def visit_ImportFrom(self, node):
        mod = node.module or ""
        for a in node.names:
            self.names[a.asname or a.name] = f"{mod}.{a.name}" if mod else a.name


def _dotted(node) -> Optional[str]:
    """Attribute/Name chain -> dotted string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _resolve(dotted: str, names: Dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    base = names.get(head, head)
    return f"{base}.{rest}" if rest else base


def lint_source(source: str, path: str = "<string>") -> List[LintViolation]:
    """Lint one module's source. `path` is used for rule scoping (the shim
    exemption, the wire-package check) and reporting — pass a path
    relative to the repo root when you have one."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintViolation("C000", path, e.lineno or 0,
                              f"syntax error: {e.msg}")]
    lines = source.splitlines()
    allows = _allowances(lines)
    imports = _Imports()
    imports.visit(tree)
    names = imports.names
    norm = path.replace("\\", "/")
    is_shim = norm.endswith(_SHIM)
    in_wire_pkg = "/wire/" in norm or norm.endswith("/wire")

    found: List[LintViolation] = []

    def emit(rule: str, line: int, msg: str):
        allow = allows.get(line, {}).get(rule, "missing")
        if allow is None:
            found.append(LintViolation(
                rule, path, line,
                f"allow({rule}) needs a justification: "
                f"`# lint: allow({rule}) -- <why>`",
            ))
        elif allow == "missing":
            found.append(LintViolation(rule, path, line, msg))
        # else: suppressed with a recorded justification

    for node in ast.walk(tree):
        # ---- C001: raw collective surface -----------------------------
        if not is_shim:
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod in _RAW_MODULES:
                    for a in node.names:
                        if a.name in BANNED_COLLECTIVES:
                            emit(
                                "C001", node.lineno,
                                f"importing {a.name!r} from {mod} — route it "
                                f"through repro.parallel.collectives "
                                f"({LINT_RULES['C001']})",
                            )
            if isinstance(node, (ast.Attribute, ast.Name)):
                dotted = _dotted(node)
                if dotted and "." in dotted:
                    resolved = _resolve(dotted, names)
                    mod, _, member = resolved.rpartition(".")
                    if member in BANNED_COLLECTIVES and mod in _RAW_MODULES:
                        emit(
                            "C001", node.lineno,
                            f"raw {resolved} — route it through "
                            f"repro.parallel.collectives "
                            f"({LINT_RULES['C001']})",
                        )
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                target = names.get(node.func.id, "")
                mod, _, member = target.rpartition(".")
                if member in BANNED_COLLECTIVES and mod in _RAW_MODULES:
                    emit(
                        "C001", node.lineno,
                        f"call to {target} (imported as {node.func.id!r}) — "
                        f"route it through repro.parallel.collectives",
                    )

        # ---- C002: Optimizer(...) contract ----------------------------
        if isinstance(node, ast.Call):
            callee = None
            if isinstance(node.func, ast.Name):
                callee = node.func.id
            elif isinstance(node.func, ast.Attribute):
                callee = node.func.attr
            if callee == "Optimizer":
                kw = {k.arg for k in node.keywords}
                missing = [k for k in ("dx_scale", "fused_kernel")
                           if k not in kw]
                if missing and None not in kw:  # **kwargs splat: can't tell
                    emit(
                        "C002", node.lineno,
                        f"Optimizer(...) without explicit "
                        f"{' and '.join(missing)} — every optimizer must "
                        f"declare its §4.1 Δx rescale and its fused-kernel "
                        f"capability ({LINT_RULES['C002']})",
                    )

        # ---- C003: WireFormat locality --------------------------------
        if isinstance(node, ast.ClassDef) and not in_wire_pkg:
            for base in node.bases:
                base_name = (
                    base.id if isinstance(base, ast.Name)
                    else base.attr if isinstance(base, ast.Attribute)
                    else None
                )
                if base_name == "WireFormat":
                    emit(
                        "C003", node.lineno,
                        f"WireFormat subclass {node.name!r} outside "
                        f"src/repro/wire/ — the codec registry, psum-safety "
                        f"tests and wire auditor enumerate that package "
                        f"({LINT_RULES['C003']})",
                    )
    # de-duplicate (an Attribute inside a Call is visited twice)
    uniq = {}
    for v in found:
        uniq.setdefault((v.rule, v.line, v.message), v)
    return sorted(uniq.values(), key=lambda v: (v.path, v.line, v.rule))


def lint_paths(paths: Sequence[str]) -> List[LintViolation]:
    out: List[LintViolation] = []
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            out.extend(lint_source(f.read_text(), str(f)))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if not args:
        print("usage: python -m repro.analysis.lint <path> [path ...]")
        return 2
    violations = lint_paths(args)
    for v in violations:
        print(v)
    print(f"lint: {len(violations)} violation(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
