"""The floatless-wire auditor: static proof of the integer wire on a jaxpr.

Rules (W = wire; violations carry these ids):

  W001  floatless dp wire — no floating-dtype operand on a REDUCING
        collective over the data-parallel axes. Scalar loss/metric
        reductions (≤ ``scalar_allowance`` elements) are allowed; ZeRO-1's
        bf16 param all-gathers are a gather, not a reduce, and are exempt.
        Integer GATHERS above the allowance are wire payload and must be
        declared: allowed only when the spec's codec transport is "gather"
        (TopKInt's idx/vals planes) or overlap is "ring" (the ring route
        finishes with an integer all-gather); otherwise they are flagged
        as undeclared wire traffic.
  W002  wire range safety — every integer operand of a reducing dp-axis
        collective is *provably bounded* by the forward interval pass, fits
        its transport lane after the n-worker sum, and the declared
        (kind, bits, n_workers, n_accum) chain proof
        (:func:`repro.analysis.intervals.wire_chain_proof`) holds — also
        for every clip bound OBSERVED in the jaxpr upstream of the wire
        (a clip looser than the declared §5.1 limit, e.g. a forgotten
        ``n_accum``, re-proves with the observed bound and fails).
  W003  fused-route image locality — with the packed codec the unpacked
        integer image must never materialize in HBM between the wire and
        the Pallas update kernel: every pallas_call consuming int32 at
        image size (rather than packed-word size) is flagged.

Suppression: a rule can be waived for one audit by passing
``suppress={"W00x": "justification"}`` — the justification string is
recorded in the report (empty justifications are rejected), mirroring the
lint-side ``# lint: allow(C00x) -- why`` escape hatch.

The auditor trusts the Pallas kernels' *internal* arithmetic (their
encode/pack parity with the jnp reference is pinned by tests/test_kernels
and tests/test_wire_pack); when ``spec.use_kernels`` is set, kernel
outputs get the declared stage bounds instead of TOP.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis import jaxpr_walk as jw
from repro.analysis import intervals as iv
from repro.analysis.intervals import Interval, TOP

__all__ = [
    "RULES",
    "SCALAR_REDUCE_ALLOWANCE",
    "WireSpec",
    "Violation",
    "AuditReport",
    "WireAuditError",
    "audit_jaxpr",
    "audit_step",
    "spec_for_step",
]

RULES = {
    "W001": "no float operand on a reducing dp-axis collective; integer "
            "gathers only when the spec declares a gather-transport codec "
            "or the ring route (float gathers exempt)",
    "W002": "integer wire operands provably bounded; §5.1 guard-bit chain "
            "proof holds for declared AND jaxpr-observed clip bounds",
    "W003": "packed fused route: unpacked integer image never "
            "materializes in HBM between wire and Pallas kernel",
}

_LANE_MAX = {"int8": 127, "int16": 32767}

# W001's escape hatch for float reductions that are METRICS, not gradient
# payload: the loss mean, grad-norm/clip scalars, the stacked per-leaf
# ||·||² vector of _global_reduce_leaf_sq. 64 elements ≈ the largest leaf
# COUNT a shipped config stacks into one such vector, and is 4+ orders of
# magnitude below the smallest gradient leaf — so a float gradient can never
# hide under the allowance, while per-leaf diagnostics always fit. The
# 64/65 boundary is pinned by tests/test_analysis.py.
SCALAR_REDUCE_ALLOWANCE = 64


class WireAuditError(AssertionError):
    """Raised by ``AuditReport.raise_if_failed`` / ``verify='static'``."""


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """The declared wire configuration one audit verifies against — the
    dp-axis tagging plus codec/pipelining facts ``build_train_step``
    attaches to its :class:`~repro.launch.step.StepArtifacts`."""

    dp_axes: Tuple[str, ...]
    axis_sizes: Dict[str, int]  # ALL mesh axes (collective scaling)
    n_workers: int
    n_accum: int = 1
    wire_kind: str = "dense"  # "dense" | "packed" | "topk"
    bits: int = 32
    use_kernels: bool = False
    fused: bool = False
    scalar_allowance: int = SCALAR_REDUCE_ALLOWANCE
    # transport declaration (PR 9) — what the traffic accountant and the
    # schedule analyzer prove the trace against. ``leaf_sizes`` is the
    # element count of each LOCAL param leaf (the integer image the codec
    # packs), in flatten order; ``overlap``/``bucket_words`` mirror the
    # CommCtx the step was built with. Empty leaf_sizes = unknown payload
    # (hand-built specs): the byte/count equality rules are skipped.
    leaf_sizes: Tuple[int, ...] = ()
    overlap: str = "off"
    bucket_words: int = 0
    # sparse/multi-plane declaration (PR 10) — ``wire_transport`` is the
    # codec's declared collective shape ("psum" | "gather"); ``topk_k`` is
    # the per-leaf selection size for kind "topk" (0 otherwise).
    wire_transport: str = "psum"
    topk_k: int = 0

    @property
    def lim(self) -> int:
        """Declared clip limit for this codec: the §5.1 n·M-divided bound
        for summing transports, the full int-range for gather kinds."""
        return iv.declared_clip_limit(
            self.wire_kind, self.n_workers * self.n_accum, self.bits
        )

    @property
    def dp_sizes(self) -> Tuple[int, ...]:
        return tuple(self.axis_sizes.get(a, 1) for a in self.dp_axes)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["axis_sizes"] = dict(d["axis_sizes"])
        return d


def _unwrap_wire(wf):
    """WireFormat | Logged wrapper -> the underlying concrete format."""
    while hasattr(wf, "inner"):
        wf = wf.inner
    return wf


def spec_for_step(layout, wf, *, n_accum: int = 1, fused: bool = False) -> WireSpec:
    """Build the audit spec from a resolved launch layout + wire format.

    Besides the codec facts, the spec declares the step's TRANSPORT: per-leaf
    integer-image sizes (from the layout's local param structs) and the
    overlap/bucketing mode from its CommCtx — everything the static byte
    accountant (:mod:`repro.analysis.traffic`) needs to reconstruct, without
    executing, exactly what the ``Logged`` codec would meter at trace time."""
    import math

    wf = _unwrap_wire(wf)
    leaf_sizes = tuple(
        int(math.prod(l.shape)) for l in _tree_leaves(layout.l_shapes)
    )
    ctx = getattr(layout, "ctx", None)
    return WireSpec(
        dp_axes=tuple(layout.dp),
        axis_sizes=dict(layout.mesh.shape),
        n_workers=layout.n_dp,
        n_accum=n_accum,
        wire_kind=str(wf.name),
        bits=int(wf.bits),
        use_kernels=bool(getattr(wf, "use_kernels", False)),
        fused=fused,
        leaf_sizes=leaf_sizes,
        overlap=getattr(ctx, "overlap", "off"),
        bucket_words=int(getattr(ctx, "bucket_words", 0)),
        wire_transport=str(getattr(wf, "transport", "psum")),
        topk_k=int(getattr(wf, "k", 0)),
    )


def _tree_leaves(tree):
    import jax  # deferred: the lint half of repro.analysis is jax-free

    return jax.tree.leaves(tree)


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    where: str  # primitive@axes dtype(shape) — or chain:<stage> for proofs
    message: str

    def __str__(self):
        return f"[{self.rule}] {self.where}: {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AuditReport:
    spec: WireSpec
    proof: iv.ChainProof
    violations: Tuple[Violation, ...]
    suppressed: Tuple[Tuple[Violation, str], ...]
    stats: Dict[str, int]

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_failed(self):
        if not self.ok:
            lines = "\n".join(f"  {v}" for v in self.violations)
            raise WireAuditError(
                f"floatless-wire audit failed "
                f"({len(self.violations)} violation(s)):\n{lines}"
            )

    def to_dict(self) -> dict:
        return {
            "spec": self.spec.to_dict(),
            "proof": {
                "lim": self.proof.lim,
                "stages": {
                    k: [s.lo, s.hi] for k, s in self.proof.stages.items()
                },
            },
            "violations": [v.to_dict() for v in self.violations],
            "suppressed": [
                {**v.to_dict(), "justification": j} for v, j in self.suppressed
            ],
            "stats": dict(self.stats),
            "ok": self.ok,
        }


# --------------------------------------------------------------------------
# cross-scope dataflow graph — the generic defs/uses/links construction and
# plain backward reachability live in jaxpr_walk (promoted there in PR 9 so
# the schedule analyzer shares them); this module keeps only the WIRE-path
# restricted walk below.
# --------------------------------------------------------------------------
_is_var = jw.is_var

# Primitives a value may pass through between its §5.1 clip and the dp
# collective: rounding, scaling, lane casts, bit-packing, bucketing and the
# ring transport. The clip-attribution walk stops at anything else (matmuls,
# gathers, reductions), so data-path clips deep in the model — token-id
# clips, logit caps — are NOT mistaken for wire clips. schedule.py's P002
# round-trip rule keys off the same set: a cast is "on the wire path" iff
# this walk reaches it.
WIRE_PATH_PRIMS = frozenset({
    "convert_element_type", "bitcast_convert_type", "reshape",
    "broadcast_in_dim", "squeeze", "transpose", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "add", "sub", "mul",
    "neg", "max", "min", "clamp", "abs", "sign", "floor", "round", "rem",
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "select_n", "stop_gradient",
    "optimization_barrier", "copy", "ppermute", "all_gather", "psum",
})


def backward_wire_eqns(roots, graph: jw.DataflowGraph) -> set:
    """Like :func:`jaxpr_walk.backward_eqns` but only walks THROUGH
    wire-path primitives; call/scan scopes are crossed via equality links
    (never by jumping a call eqn's invars, which would tunnel past its
    body)."""
    seen_vars: set = set()
    hit: set = set()
    stack = [r for r in roots if _is_var(r)]
    while stack:
        v = stack.pop()
        if id(v) in seen_vars:
            continue
        seen_vars.add(id(v))
        eqn = graph.defs.get(id(v))
        if eqn is not None and id(eqn) not in hit:
            hit.add(id(eqn))
            if (next(jw.eqn_subjaxprs(eqn), None) is None
                    and eqn.primitive.name in WIRE_PATH_PRIMS):
                stack.extend(a for a in eqn.invars if _is_var(a))
        stack.extend(graph.links.get(id(v), ()))
    return hit


# --------------------------------------------------------------------------
# the audit
# --------------------------------------------------------------------------
def _fmt_where(eqn, axes) -> str:
    a = eqn.invars[0].aval if eqn.invars else eqn.outvars[0].aval
    return (
        f"{eqn.primitive.name}@{','.join(axes)} "
        f"{a.dtype}{tuple(a.shape)}"
    )


def _pallas_override(spec: WireSpec, proof: iv.ChainProof):
    """Trusted-kernel transfer for pallas_call when the codec routes its
    hot stages through the Pallas kernels: integer outputs of an
    encode-style call (float in, int out) get the declared accumulator
    bound; word-producing calls (int in, int out) get the 32-bit word
    range (bounded, field-level safety comes from the chain proof)."""
    acc = proof.stages["accum"]
    word = Interval(-(2 ** 31), 2 ** 32 - 1)

    def run(eqn, ins):
        any_float_in = any(
            getattr(v.aval, "dtype", None) is not None
            and v.aval.dtype.kind == "f"
            for v in eqn.invars
        )
        outs = []
        for ov in eqn.outvars:
            if ov.aval.dtype.kind == "i":
                outs.append(acc if any_float_in else word)
            else:
                outs.append(TOP)
        return outs

    return run


def audit_jaxpr(
    closed_jaxpr,
    spec: WireSpec,
    *,
    suppress: Optional[Dict[str, str]] = None,
) -> AuditReport:
    """Statically verify the floatless-wire contract on a traced step."""
    suppress = dict(suppress or {})
    for rule, why in suppress.items():
        if rule not in RULES:
            raise ValueError(f"unknown rule {rule!r} in suppress")
        if not str(why).strip():
            raise ValueError(
                f"suppressing {rule} requires a non-empty justification"
            )

    violations: List[Violation] = []
    proof = iv.wire_chain_proof(
        spec.wire_kind, spec.bits, spec.n_workers, spec.n_accum
    )
    for check_id, msg in proof.violations:
        violations.append(Violation("W002", f"chain:{check_id}", msg))

    # ---- forward interval pass, observing every eqn -------------------
    obs: Dict[int, list] = {}
    order: List[int] = []

    def on_eqn(eqn, ins, outs):
        rec = obs.get(id(eqn))
        if rec is None:
            obs[id(eqn)] = [eqn, list(ins), list(outs)]
            order.append(id(eqn))
        else:  # an eqn replayed per scan iteration: union the observations
            rec[1] = [a.union(b) for a, b in zip(rec[1], ins)]
            rec[2] = [a.union(b) for a, b in zip(rec[2], outs)]

    overrides = (
        {"pallas_call": _pallas_override(spec, proof)}
        if spec.use_kernels
        else None
    )
    iv.eval_jaxpr_intervals(
        closed_jaxpr,
        axis_sizes=spec.axis_sizes,
        prim_overrides=overrides,
        on_eqn=on_eqn,
    )

    stats = {
        "eqns": len(order),
        "dp_collectives": 0,
        "int_wire_ops": 0,
        "scalar_float_reduces": 0,
        "clips_checked": 0,
        "pallas_calls": 0,
    }
    dp = set(spec.dp_axes)
    wire_roots: List = []  # int operands of reducing dp collectives

    for key in order:
        eqn, ins, _outs = obs[key]
        name = eqn.primitive.name
        if name == "pallas_call":
            stats["pallas_calls"] += 1
        if name not in jw.COLLECTIVES:
            continue
        axes = jw.eqn_axes(eqn)
        if not (set(axes) & dp):
            continue  # model/sp-axis collective: TP floats are by design
        stats["dp_collectives"] += 1
        if name not in jw.REDUCING_COLLECTIVES:
            # A non-reducing dp collective (all-gather) moves data without
            # combining it. Float gathers stay exempt — ZeRO-1's bf16 param
            # all-gathers are legitimate non-wire traffic. INTEGER gathers
            # above the scalar allowance ARE wire payload, though, and must
            # be declared: either the codec's transport is "gather"
            # (TopKInt's idx/vals planes) or the ring route's finishing
            # all_gather under overlap="ring". Declared gather operands
            # join wire_roots (so the observed-clip re-proof covers their
            # upstream clamps) but carry NO boundedness requirement —
            # nothing sums on a gather wire, two's-complement fields are
            # lossless, and the decode-side scatter-add bound is the chain
            # proof's image_sum check.
            gather_declared = (
                spec.wire_transport == "gather" or spec.overlap == "ring"
            )
            for operand, ival in zip(eqn.invars, ins):
                aval = getattr(operand, "aval", None)
                if aval is None or not hasattr(aval, "dtype"):
                    continue
                if aval.dtype.kind != "i":
                    continue
                nelem = jw.aval_nelem(aval)
                if nelem <= spec.scalar_allowance:
                    continue
                if gather_declared:
                    stats["int_wire_ops"] += 1
                    wire_roots.append(operand)
                else:
                    violations.append(Violation(
                        "W001", _fmt_where(eqn, axes),
                        f"undeclared integer gather: {aval.dtype} tensor of "
                        f"{nelem} elements rides a {jw.COLLECTIVES[name]} "
                        f"over dp axes {axes}, but the spec declares a "
                        f"'{spec.wire_transport}' transport with "
                        f"overlap='{spec.overlap}' — integer payload on a "
                        f"gather must come from a gather-transport codec or "
                        f"the ring route's finishing all-gather",
                    ))
            continue
        n_ax = 1
        for a in axes:
            n_ax *= spec.axis_sizes.get(a, 1)
        for operand, ival in zip(eqn.invars, ins):
            aval = getattr(operand, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            kind = aval.dtype.kind
            nelem = jw.aval_nelem(aval)
            where = _fmt_where(eqn, axes)
            if kind == "f":
                if nelem <= spec.scalar_allowance:
                    stats["scalar_float_reduces"] += 1
                else:
                    violations.append(Violation(
                        "W001", where,
                        f"float {aval.dtype} tensor of {nelem} elements on a "
                        f"{jw.COLLECTIVES[name]} over dp axes {axes} — the "
                        f"wire must carry integers (scalar allowance is "
                        f"{spec.scalar_allowance} elements)",
                    ))
            elif kind == "i":
                stats["int_wire_ops"] += 1
                wire_roots.append(operand)
                if not ival.bounded:
                    violations.append(Violation(
                        "W002", where,
                        "integer wire operand is not provably bounded — no "
                        "clip dominates this value on its way to the "
                        "collective",
                    ))
                    continue
                lane = _LANE_MAX.get(str(aval.dtype))
                if lane is not None:
                    # narrow dense lane: a psum multiplies the per-worker
                    # value by the axis product; a ring hop's operand
                    # already contains its accumulated partials
                    post = ival.scale(n_ax) if name != "ppermute" else ival
                    if post.mag > lane:
                        violations.append(Violation(
                            "W002", where,
                            f"lane overflow: |value| ≤ {int(post.mag)} after "
                            f"the {n_ax}-worker sum exceeds the "
                            f"{aval.dtype} range ±{lane}",
                        ))

    # ---- observed-clip re-proof (forgot-n_accum bug class) -------------
    if wire_roots:
        graph = jw.build_graph(closed_jaxpr)
        upstream = backward_wire_eqns(wire_roots, graph)
        # The §5.1 clip runs in the float domain just before the cast to the
        # lane dtype (round → clip → astype), so a clamp counts as a WIRE
        # clip when its output is integer OR is consumed by an int
        # convert_element_type inside the wire's backward slice. Plain float
        # clamps deeper in the model graph (logit caps etc.) stay excluded.
        int_convert_srcs: set = set()
        for key in order:
            eqn, _ins, _outs = obs[key]
            if (eqn.primitive.name == "convert_element_type"
                    and id(eqn) in upstream
                    and eqn.outvars[0].aval.dtype.kind == "i"):
                int_convert_srcs.update(
                    id(v) for v in eqn.invars if _is_var(v)
                )
        for key in order:
            eqn, ins, _outs = obs[key]
            if id(eqn) not in upstream:
                continue
            name = eqn.primitive.name
            if name == "clamp":  # lax.clamp(min, x, max)
                lo, hi = ins[0], ins[2]
            elif (name in jw.CALL_PRIMS
                    and eqn.params.get("name") == "clip"
                    and len(ins) == 3):  # jnp.clip -> pjit[name=clip](x, lo, hi)
                lo, hi = ins[1], ins[2]
            else:
                continue
            if (eqn.outvars[0].aval.dtype.kind != "i"
                    and id(eqn.outvars[0]) not in int_convert_srcs):
                continue
            if not (lo.bounded and hi.bounded):
                continue
            stats["clips_checked"] += 1
            l_obs = int(max(abs(lo.lo), abs(hi.hi)))
            if l_obs <= spec.lim:
                continue
            re_proof = iv.wire_chain_proof(
                spec.wire_kind, spec.bits, spec.n_workers, spec.n_accum,
                lim=l_obs,
            )
            for check_id, msg in re_proof.violations:
                violations.append(Violation(
                    "W002",
                    f"{_fmt_where(eqn, ())}→wire",
                    f"observed clip |v| ≤ {l_obs} is looser than the "
                    f"declared §5.1 limit {spec.lim} and breaks the chain "
                    f"proof [{check_id}]: {msg}",
                ))

    # ---- fused-route image locality ------------------------------------
    if spec.fused and spec.wire_kind == "packed":
        for key in order:
            eqn, _ins, _outs = obs[key]
            if eqn.primitive.name != "pallas_call":
                continue
            image = max(
                (jw.aval_nelem(v.aval) for v in eqn.outvars
                 if v.aval.dtype.kind == "f"),
                default=0,
            )
            if not image:
                continue
            for operand in eqn.invars:
                aval = operand.aval
                if (aval.dtype.kind == "i"
                        and jw.aval_nelem(aval) > (image * 3) // 4):
                    violations.append(Violation(
                        "W003",
                        f"pallas_call {aval.dtype}{tuple(aval.shape)}",
                        f"int32 kernel operand of {jw.aval_nelem(aval)} "
                        f"elements is image-sized (image {image}): the "
                        f"unpacked integer image took an HBM round-trip "
                        f"instead of riding the packed words "
                        f"(expected ≤ {image // (32 // spec.bits)} words)",
                    ))

    kept: List[Violation] = []
    suppressed: List[Tuple[Violation, str]] = []
    for v in violations:
        if v.rule in suppress:
            suppressed.append((v, suppress[v.rule]))
        else:
            kept.append(v)
    return AuditReport(
        spec=spec,
        proof=proof,
        violations=tuple(kept),
        suppressed=tuple(suppressed),
        stats=stats,
    )


def audit_step(artifacts, which: str = "compressed", **kw) -> AuditReport:
    """Trace one jitted variant of a built step and audit it against the
    spec the builder attached (``StepArtifacts.audit_spec``)."""
    import jax  # deferred: the lint half of repro.analysis is jax-free

    spec = getattr(artifacts, "audit_spec", None)
    if spec is None:
        raise ValueError(
            "StepArtifacts carries no audit_spec — build the step with "
            "repro.launch.step.build_train_step (PR 8+) or pass audit_jaxpr "
            "an explicit WireSpec"
        )
    jaxpr = jax.make_jaxpr(artifacts.jitted[which])(*artifacts.arg_structs)
    return audit_jaxpr(jaxpr, spec, **kw)
