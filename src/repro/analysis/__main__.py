"""``python -m repro.analysis --matrix`` — sweep the floatless-wire audit
over the supported (config × codec × overlap × microbatch) grid, run the
contract linter, and write ``ANALYSIS_report.json``.

Every point builds the real train step (``build_train_step``) on a forced
4-host-device mesh, traces it, and runs :func:`repro.analysis.wire_audit
.audit_jaxpr` — trace only, nothing is compiled or executed. A few fused
points ride along for W003 coverage. ``--check`` exits non-zero on any
violation (the CI tier-1 wiring).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEFAULT_CODECS = ("dense8", "packed8")
DEFAULT_OVERLAPS = ("off", "ring")
DEFAULT_MICROBATCHES = (1, 4)


def _parse_args(argv):
    p = argparse.ArgumentParser(prog="python -m repro.analysis")
    p.add_argument("--matrix", action="store_true",
                   help="sweep the audit over the supported grid")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero on any lint/audit violation")
    p.add_argument("--configs", default=None,
                   help="comma-separated arch subset (default: all shipped)")
    p.add_argument("--codecs", default=",".join(DEFAULT_CODECS))
    p.add_argument("--overlaps", default=",".join(DEFAULT_OVERLAPS))
    p.add_argument("--microbatches", default=",".join(map(str, DEFAULT_MICROBATCHES)))
    p.add_argument("--no-fused-points", action="store_true",
                   help="skip the extra fused-route (W003) coverage points")
    p.add_argument("--report", default="ANALYSIS_report.json")
    p.add_argument("--devices", type=int, default=4)
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(sys.argv[1:] if argv is None else argv)
    if not args.matrix:
        print("nothing to do: pass --matrix (or use `python -m "
              "repro.analysis.lint <paths>` for the linter alone)")
        return 2

    # the forced-device env must be set before jax is first imported
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}",
    )
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    from repro.analysis import lint as lint_mod
    from repro.analysis import wire_audit
    from repro.configs import ARCHS, ShapeConfig, get_arch, smoke_config
    from repro.configs.base import _load as _load_archs
    from repro.core import make_compressor
    from repro.launch.step import build_train_step
    from repro.wire import make_wire_format
    from repro.optim import sgd
    from repro.optim.schedules import constant

    src_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    lint_violations = lint_mod.lint_paths([src_root])
    for v in lint_violations:
        print(f"LINT {v}")

    _load_archs()
    configs = (
        [c.strip() for c in args.configs.split(",") if c.strip()]
        if args.configs
        else sorted(ARCHS)
    )
    codecs = [c.strip() for c in args.codecs.split(",") if c.strip()]
    overlaps = [o.strip() for o in args.overlaps.split(",") if o.strip()]
    micro = [int(m) for m in args.microbatches.split(",") if m.strip()]

    mesh = jax.make_mesh((args.devices, 1), ("data", "model"))
    # local batch must divide into every microbatch count
    lcm = 1
    for m in micro:
        lcm = lcm * m // _gcd(lcm, m)
    shape = ShapeConfig("analysis", 64, args.devices * lcm, "train")

    points = [
        (arch, codec, ov, m, False)
        for arch in configs
        for codec in codecs
        for ov in overlaps
        for m in micro
    ]
    if not args.no_fused_points and configs:
        # fused route only supports M=1; packed point exercises W003,
        # dense point pins the fused dense image as in-contract
        points += [
            (configs[0], "packed8", "off", 1, True),
            (configs[0], "dense8", "off", 1, True),
        ]

    results = []
    t_all = time.time()
    for arch, codec, ov, m, fused in points:
        label = f"{arch} × {codec} × overlap={ov} × M={m}" + (
            " × fused" if fused else ""
        )
        t0 = time.time()
        try:
            art = build_train_step(
                smoke_config(get_arch(arch)),
                mesh,
                shape,
                compressor=make_compressor(
                    "intsgd", bits=make_wire_format(codec).bits, wire=codec
                ),
                base_opt=sgd(momentum=0.9),
                lr_schedule=constant(0.1),
                tp_override=1,
                fused=fused,
                overlap=ov,
                microbatches=m,
            )
            report = wire_audit.audit_step(art)
            entry = {
                "config": arch, "codec": codec, "overlap": ov,
                "microbatches": m, "fused": fused,
                **report.to_dict(),
            }
        except Exception as e:  # a build failure is a matrix failure
            entry = {
                "config": arch, "codec": codec, "overlap": ov,
                "microbatches": m, "fused": fused,
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "violations": [],
            }
        entry["seconds"] = round(time.time() - t0, 2)
        results.append(entry)
        status = "OK" if entry["ok"] else "FAIL"
        print(f"audit {label}: {status} ({entry['seconds']}s)")
        if not entry["ok"]:
            for v in entry.get("violations", []):
                print(f"    [{v['rule']}] {v['where']}: {v['message']}")
            if "error" in entry:
                print(f"    build error: {entry['error']}")

    ok = not lint_violations and all(r["ok"] for r in results)
    artifact = {
        "grid": {
            "configs": configs, "codecs": codecs, "overlaps": overlaps,
            "microbatches": micro,
            "mesh": {"data": args.devices, "model": 1},
        },
        "lint": [v.to_dict() for v in lint_violations],
        "points": results,
        "ok": ok,
        "seconds": round(time.time() - t_all, 2),
    }
    with open(args.report, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
    n_bad = sum(not r["ok"] for r in results)
    print(
        f"matrix: {len(results)} points, {n_bad} failing, "
        f"{len(lint_violations)} lint violation(s) -> {args.report} "
        f"({artifact['seconds']}s)"
    )
    if args.check and not ok:
        return 1
    return 0


def _gcd(a, b):
    while b:
        a, b = b, a % b
    return a


if __name__ == "__main__":
    raise SystemExit(main())
