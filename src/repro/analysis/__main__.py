"""``python -m repro.analysis --matrix`` — sweep the full static audit
(W wire rules + P schedule rules + T traffic rules) over the supported
(config × codec × overlap × microbatch) grid, run the contract linter over
``src/`` + ``tests/`` + ``benchmarks/``, and write ``ANALYSIS_report.json``
plus the static-roofline table ``ANALYSIS_roofline.json``.

Every point builds the real train step (``build_train_step``) on a forced
4-host-device mesh, traces it, and runs :func:`repro.analysis.schedule
.full_audit` — trace only, nothing is compiled or executed. Each point's
entry carries ``schedule`` (overlap classification + roofline fractions)
and ``traffic`` (declared-vs-observed wire bytes/counts) sections next to
the W-layer fields. A few fused points ride along for W003/P003 coverage.

``--check`` exits non-zero on any violation (the CI tier-1 wiring).
``--diff`` compares the fresh sweep against the COMMITTED report instead of
rewriting it: new/removed grid points, flipped verdicts, or changed
violation sets fail the run — so a contract change must land with an
explicit report regeneration, never as a silent artifact diff.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEFAULT_CODECS = ("dense8", "packed8", "topk8:64")
DEFAULT_OVERLAPS = ("off", "ring")
DEFAULT_MICROBATCHES = (1, 4)

# (config, codec, overlap, microbatches, fused) — the identity of one grid
# point; everything else in an entry is a verdict about it
POINT_KEY = ("config", "codec", "overlap", "microbatches", "fused")


def _parse_args(argv):
    p = argparse.ArgumentParser(prog="python -m repro.analysis")
    p.add_argument("--matrix", action="store_true",
                   help="sweep the audit over the supported grid")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero on any lint/audit violation")
    p.add_argument("--diff", action="store_true",
                   help="compare against the committed report instead of "
                        "rewriting it; exit non-zero on any drift")
    p.add_argument("--configs", default=None,
                   help="comma-separated arch subset (default: all shipped)")
    p.add_argument("--codecs", default=",".join(DEFAULT_CODECS))
    p.add_argument("--overlaps", default=",".join(DEFAULT_OVERLAPS))
    p.add_argument("--microbatches", default=",".join(map(str, DEFAULT_MICROBATCHES)))
    p.add_argument("--no-fused-points", action="store_true",
                   help="skip the extra fused-route (W003/P003) coverage points")
    p.add_argument("--report", default="ANALYSIS_report.json")
    p.add_argument("--roofline", default="ANALYSIS_roofline.json",
                   help="where to write the static-roofline table artifact")
    p.add_argument("--devices", type=int, default=4)
    return p.parse_args(argv)


def _point_key(entry) -> tuple:
    return tuple(entry[k] for k in POINT_KEY)


def _fmt_key(key: tuple) -> str:
    return " × ".join(f"{k}={v}" for k, v in zip(POINT_KEY, key))


def _verdict(entry) -> dict:
    """The drift-relevant slice of a point entry: the verdict and the rule
    ids behind it — never timing, never message text (both churn freely)."""
    return {
        "ok": bool(entry.get("ok")),
        "rules": sorted({v["rule"] for v in entry.get("violations", [])}),
        "error": "error" in entry,
    }


def _diff_reports(old: dict, new: dict) -> list:
    """Human-readable drift lines between two matrix reports ([] = none).

    Compares the grid point SET and each point's verdict (`ok` + violation
    rule ids + build-error-ness); ignores timings, roofline numbers and
    violation message wording so a jax version bump doesn't trip it."""
    drift = []
    old_pts = {_point_key(e): e for e in old.get("points", [])}
    new_pts = {_point_key(e): e for e in new.get("points", [])}
    for key in sorted(old_pts.keys() - new_pts.keys()):
        drift.append(f"point removed: {_fmt_key(key)}")
    for key in sorted(new_pts.keys() - old_pts.keys()):
        drift.append(f"point added: {_fmt_key(key)}")
    for key in sorted(old_pts.keys() & new_pts.keys()):
        was, now = _verdict(old_pts[key]), _verdict(new_pts[key])
        if was != now:
            drift.append(
                f"verdict changed: {_fmt_key(key)}: "
                f"ok {was['ok']}->{now['ok']}, "
                f"rules {was['rules']}->{now['rules']}"
                + (", build error appeared" if now["error"] and not was["error"]
                   else ", build error gone" if was["error"] and not now["error"]
                   else "")
            )
    if bool(old.get("lint")) != bool(new.get("lint")):
        drift.append(
            f"lint drift: {len(old.get('lint', []))} committed violation(s) "
            f"vs {len(new.get('lint', []))} fresh"
        )
    return drift


def _roofline_rows(results) -> list:
    """Flatten each point's schedule/traffic sections into one table row —
    the artifact CI uploads and bench_overlap cross-checks statically."""
    rows = []
    for e in results:
        sched = e.get("schedule") or {}
        traffic = e.get("traffic") or {}
        declared = traffic.get("declared") or {}
        rows.append({
            **{k: e[k] for k in POINT_KEY},
            "ok": e["ok"],
            "n_wire_collectives": sched.get("n_wire_collectives"),
            "n_serialized": sched.get("n_serialized"),
            "total_wire_bytes": sched.get("total_wire_bytes"),
            "hidden_fraction": sched.get("hidden_fraction"),
            "interleavable_fraction": sched.get("interleavable_fraction"),
            "backward_flops": sched.get("backward_flops"),
            "declared_bytes": declared.get("coll_bytes"),
            "declared_eqns": declared.get("n_eqns"),
            "payload_bytes_per_image": declared.get("payload_bytes"),
        })
    return rows


def main(argv=None) -> int:
    args = _parse_args(sys.argv[1:] if argv is None else argv)
    if not args.matrix:
        print("nothing to do: pass --matrix (or use `python -m "
              "repro.analysis.lint <paths>` for the linter alone)")
        return 2

    # the forced-device env must be set before jax is first imported
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}",
    )
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    from repro.analysis import lint as lint_mod
    from repro.analysis import schedule as schedule_mod
    from repro.configs import ARCHS, ShapeConfig, get_arch, smoke_config
    from repro.configs.base import _load as _load_archs
    from repro.core import make_compressor
    from repro.launch.step import build_train_step
    from repro.wire import make_wire_format
    from repro.optim import sgd
    from repro.optim.schedules import constant

    src_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    repo_root = os.path.dirname(os.path.dirname(src_root))
    # lint the harness trees too: a test that grows a raw lax.psum without a
    # justified allow is the same contract hole as one in src/
    lint_roots = [src_root] + [
        d for d in (os.path.join(repo_root, "tests"),
                    os.path.join(repo_root, "benchmarks"))
        if os.path.isdir(d)
    ]
    lint_violations = lint_mod.lint_paths(lint_roots)
    for v in lint_violations:
        print(f"LINT {v}")

    _load_archs()
    configs = (
        [c.strip() for c in args.configs.split(",") if c.strip()]
        if args.configs
        else sorted(ARCHS)
    )
    codecs = [c.strip() for c in args.codecs.split(",") if c.strip()]
    for c in codecs:
        make_wire_format(c)  # typo fails NOW with the registry's option list
    overlaps = [o.strip() for o in args.overlaps.split(",") if o.strip()]
    micro = [int(m) for m in args.microbatches.split(",") if m.strip()]

    mesh = jax.make_mesh((args.devices, 1), ("data", "model"))
    # local batch must divide into every microbatch count
    lcm = 1
    for m in micro:
        lcm = lcm * m // _gcd(lcm, m)
    shape = ShapeConfig("analysis", 64, args.devices * lcm, "train")

    points = [
        (arch, codec, ov, m, False)
        for arch in configs
        for codec in codecs
        for ov in overlaps
        for m in micro
    ]
    if not args.no_fused_points and configs:
        # fused route only supports M=1; packed point exercises W003/P003,
        # dense point pins the fused dense image as in-contract
        points += [
            (configs[0], "packed8", "off", 1, True),
            (configs[0], "dense8", "off", 1, True),
        ]

    results = []
    t_all = time.time()
    for arch, codec, ov, m, fused in points:
        label = f"{arch} × {codec} × overlap={ov} × M={m}" + (
            " × fused" if fused else ""
        )
        t0 = time.time()
        try:
            art = build_train_step(
                smoke_config(get_arch(arch)),
                mesh,
                shape,
                compressor=make_compressor(
                    "intsgd", bits=make_wire_format(codec).bits, wire=codec
                ),
                base_opt=sgd(momentum=0.9),
                lr_schedule=constant(0.1),
                tp_override=1,
                fused=fused,
                overlap=ov,
                microbatches=m,
            )
            report = schedule_mod.verify_step(art)
            entry = {
                "config": arch, "codec": codec, "overlap": ov,
                "microbatches": m, "fused": fused,
                **report.to_dict(),
            }
        except Exception as e:  # a build failure is a matrix failure
            entry = {
                "config": arch, "codec": codec, "overlap": ov,
                "microbatches": m, "fused": fused,
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "violations": [],
            }
        entry["seconds"] = round(time.time() - t0, 2)
        results.append(entry)
        status = "OK" if entry["ok"] else "FAIL"
        sched = entry.get("schedule") or {}
        extra = ""
        if sched:
            extra = (
                f" [coll={sched['n_wire_collectives']}"
                f" hidden={sched['hidden_fraction']:.2f}"
                f" inter={sched['interleavable_fraction']:.2f}]"
            )
        print(f"audit {label}: {status}{extra} ({entry['seconds']}s)")
        if not entry["ok"]:
            for v in entry.get("violations", []):
                print(f"    [{v['rule']}] {v['where']}: {v['message']}")
            if "error" in entry:
                print(f"    build error: {entry['error']}")

    ok = not lint_violations and all(r["ok"] for r in results)
    artifact = {
        "grid": {
            "configs": configs, "codecs": codecs, "overlaps": overlaps,
            "microbatches": micro,
            "mesh": {"data": args.devices, "model": 1},
        },
        "lint": [v.to_dict() for v in lint_violations],
        "points": results,
        "ok": ok,
        "seconds": round(time.time() - t_all, 2),
    }

    # roofline table: always written (CI uploads it as a job artifact)
    roofline = {
        "grid": artifact["grid"],
        "rows": _roofline_rows(results),
        "ok": ok,
    }
    with open(args.roofline, "w") as f:
        json.dump(roofline, f, indent=2, sort_keys=True)

    drift = []
    if args.diff:
        if not os.path.exists(args.report):
            drift = [f"no committed report at {args.report} to diff against"]
        else:
            with open(args.report) as f:
                committed = json.load(f)
            drift = _diff_reports(committed, artifact)
        for line in drift:
            print(f"DIFF {line}")
        print(
            f"diff vs {args.report}: {len(drift)} drift line(s) "
            f"(report NOT rewritten; regenerate without --diff to accept)"
        )
    else:
        with open(args.report, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)

    n_bad = sum(not r["ok"] for r in results)
    print(
        f"matrix: {len(results)} points, {n_bad} failing, "
        f"{len(lint_violations)} lint violation(s) -> "
        f"{args.report if not args.diff else args.roofline} "
        f"({artifact['seconds']}s)"
    )
    if args.check and not ok:
        return 1
    if args.diff and drift:
        return 1
    return 0


def _gcd(a, b):
    while b:
        a, b = b, a % b
    return a


if __name__ == "__main__":
    raise SystemExit(main())
