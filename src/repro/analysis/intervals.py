"""Interval abstract domain + the floatless-wire range proofs.

Two layers, both pure Python over exact integer arithmetic (no jax import
needed to USE the domain; the jaxpr evaluator takes already-traced jaxprs):

1. :class:`Interval` and :func:`eval_jaxpr_intervals` — a forward abstract
   interpretation of a jaxpr in the interval domain. Transfer functions
   cover the integer wire chain exactly (clamp, add, shifts, masks, the
   collectives); everything else soundly widens to TOP. Scans are unrolled
   up to ``scan_cap`` iterations (the microbatch accumulator has static
   length M), beyond that carries widen. This is what turns "the encode
   clip makes the ring safe" from a build-time point check into a property
   of the traced program: the n-hop partial-sum growth is *derived* by the
   evaluator from the unrolled ppermute chain, not assumed.

2. :func:`wire_chain_proof` — the codec-level §5.1 proof for a declared
   (kind, bits, n_workers, n_accum): symbolic stage intervals for
   encode → M-accumulate → pack → n-worker wire sum → unpack, checked
   against the guard-bit invariant. The ``WireRangeError`` condition
   (degenerate clip limit) is one of its violations rather than a runtime
   raise. ``lim`` may be overridden with a clip bound *observed in the
   jaxpr* so a clip that is looser than the declared limit (the
   forgot-``n_accum`` bug class) fails the same proof.

tests/test_analysis.py's hypothesis suite checks soundness: concrete
random chains always land inside the derived stage intervals.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.jaxpr_walk import COLLECTIVES, eqn_axes

__all__ = [
    "Interval",
    "TOP",
    "eval_jaxpr_intervals",
    "wire_chain_proof",
    "ChainProof",
    "int_range_max",
    "safe_clip_limit",
    "declared_clip_limit",
]

_INF = math.inf

# value range of a signed `bits`-wide field (mirrors wire.base._INT_RANGE,
# duplicated so this module stays importable without jax)
_INT_RANGE = {4: 7, 8: 127, 16: 32767, 32: 2147483647}


def int_range_max(bits: int) -> int:
    return _INT_RANGE[bits]


def safe_clip_limit(n_contrib: int, bits: int) -> int:
    """§5.1 limit ``(2^(b-1)-1)//n`` WITHOUT the WireRangeError raise —
    the proof reports lim==0 as a violation instead of throwing."""
    return _INT_RANGE[bits] // max(int(n_contrib), 1)


def declared_clip_limit(kind: str, n_contrib: int, bits: int) -> int:
    """The clip limit a (kind, bits) codec declares for ``n_contrib``
    summed contributions. Psum-transport kinds divide the value range by n
    (§5.1: the sum happens ON the wire); the gather-transport "topk" kind
    clips at the full range — nothing sums until the decode-side
    scatter-add, whose int32 bound is the chain proof's job."""
    if kind == "topk":
        return _INT_RANGE[bits]
    return safe_clip_limit(n_contrib, bits)


# --------------------------------------------------------------------------
# the domain
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval [lo, hi] over the extended reals; exact (Python int)
    endpoints wherever the program is exact."""

    lo: float
    hi: float

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    # -- queries ---------------------------------------------------------
    @property
    def bounded(self) -> bool:
        return self.lo != -_INF and self.hi != _INF

    @property
    def mag(self) -> float:
        """max |v| over the interval."""
        return max(abs(self.lo), abs(self.hi))

    def contains(self, v) -> bool:
        return self.lo <= v <= self.hi

    # -- lattice ---------------------------------------------------------
    def union(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), max(self.hi, o.hi))

    # -- arithmetic ------------------------------------------------------
    def add(self, o: "Interval") -> "Interval":
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def sub(self, o: "Interval") -> "Interval":
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def neg(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def mul(self, o: "Interval") -> "Interval":
        if not (self.bounded and o.bounded):
            return TOP
        ps = [self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi]
        return Interval(min(ps), max(ps))

    def scale(self, c) -> "Interval":
        """Multiply by the exact scalar c (axis size, reduced-element count)."""
        if not self.bounded:
            return TOP
        a, b = self.lo * c, self.hi * c
        return Interval(min(a, b), max(a, b))

    def shl(self, s: "Interval") -> "Interval":
        if not (self.bounded and s.bounded) or s.lo < 0:
            return TOP
        ps = [
            int(self.lo) << int(s.lo), int(self.lo) << int(s.hi),
            int(self.hi) << int(s.lo), int(self.hi) << int(s.hi),
        ]
        return Interval(min(ps), max(ps))

    def clamp(self, lo: "Interval", hi: "Interval") -> "Interval":
        """lax.clamp(lo, x, hi): result ⊆ [lo.lo, hi.hi] REGARDLESS of x —
        this is the transfer that bounds the encode clip for TOP operands."""
        return Interval(
            max(self.lo, lo.lo) if self.bounded else lo.lo,
            min(self.hi, hi.hi) if self.bounded else hi.hi,
        )

    @staticmethod
    def point(v) -> "Interval":
        return Interval(v, v)

    @staticmethod
    def from_value(v) -> "Interval":
        """Interval of a concrete scalar/array constant."""
        import numpy as np

        a = np.asarray(v)
        if a.size == 0:
            return Interval.point(0)
        if a.dtype == bool:
            return Interval(0, 1)
        if not np.issubdtype(a.dtype, np.number):
            return TOP
        lo, hi = a.min(), a.max()
        if np.issubdtype(a.dtype, np.integer):
            return Interval(int(lo), int(hi))
        if not (np.isfinite(lo) and np.isfinite(hi)):
            return TOP
        return Interval(float(lo), float(hi))


TOP = Interval(-_INF, _INF)
_MASKABLE = Interval(0, _INF)


# --------------------------------------------------------------------------
# forward jaxpr evaluation
# --------------------------------------------------------------------------
def _passthrough(ins, eqn):
    return ins[0]


def _nelem(aval) -> int:
    n = 1
    for s in getattr(aval, "shape", ()):
        n *= int(s)
    return n


def _reduce_count(eqn) -> int:
    """#elements folded into each output element of a reduce_* eqn."""
    out = _nelem(eqn.outvars[0].aval)
    inn = _nelem(eqn.invars[0].aval)
    return max(inn // max(out, 1), 1)


def _and_transfer(ins, eqn):
    # x & mask with a known non-negative mask bounds the result to [0, mask]
    for m in ins:
        if m.bounded and m.lo >= 0:
            return Interval(0, m.hi)
    return TOP


def _scatter_add_transfer(ins, eqn):
    # out = operand with U update elements added at (possibly colliding)
    # indices: each output element receives between 0 and U updates, so
    # out ⊆ operand + hull(0, U·updates). Coarse but sound — and exactly
    # what bounds the gather wire's decode image (n·k top-k values
    # scatter-added into zeros).
    if len(ins) < 3 or not (ins[0].bounded and ins[2].bounded):
        return TOP
    U = _nelem(eqn.invars[2].aval)
    lo = min(0, ins[2].lo * U)
    hi = max(0, ins[2].hi * U)
    return ins[0].add(Interval(lo, hi))


def _top_k_transfer(ins, eqn):
    # (values, indices): values are a subset of the input, indices address
    # the input's trailing dim
    shape = getattr(eqn.invars[0].aval, "shape", ())
    d = int(shape[-1]) if shape else 1
    return [ins[0], Interval(0, max(d - 1, 0))]


_TRANSFER: Dict[str, Callable] = {
    "add": lambda ins, e: ins[0].add(ins[1]),
    "sub": lambda ins, e: ins[0].sub(ins[1]),
    "mul": lambda ins, e: ins[0].mul(ins[1]),
    "neg": lambda ins, e: ins[0].neg(),
    "max": lambda ins, e: Interval(max(ins[0].lo, ins[1].lo), max(ins[0].hi, ins[1].hi)),
    "min": lambda ins, e: Interval(min(ins[0].lo, ins[1].lo), min(ins[0].hi, ins[1].hi)),
    "clamp": lambda ins, e: ins[1].clamp(ins[0], ins[2]),
    "shift_left": lambda ins, e: ins[0].shl(ins[1]),
    "and": _and_transfer,
    "abs": lambda ins, e: Interval(0, ins[0].mag) if ins[0].bounded else _MASKABLE,
    "sign": lambda ins, e: Interval(-1, 1),
    "floor": _passthrough,
    "ceil": lambda ins, e: Interval(ins[0].lo, ins[0].hi + 1) if ins[0].bounded else TOP,
    "round": lambda ins, e: Interval(ins[0].lo - 1, ins[0].hi + 1) if ins[0].bounded else TOP,
    "convert_element_type": _passthrough,
    "reshape": _passthrough,
    "broadcast_in_dim": _passthrough,
    "transpose": _passthrough,
    "squeeze": _passthrough,
    "rev": _passthrough,
    "slice": _passthrough,
    "dynamic_slice": lambda ins, e: ins[0],
    "gather": lambda ins, e: ins[0],
    "expand_dims": _passthrough,
    "copy": _passthrough,
    "stop_gradient": _passthrough,
    "optimization_barrier": None,  # multi-out passthrough, handled below
    "concatenate": lambda ins, e: _union_all(ins),
    "pad": lambda ins, e: ins[0].union(ins[1]),
    "dynamic_update_slice": lambda ins, e: ins[0].union(ins[1]),
    "select_n": lambda ins, e: _union_all(ins[1:]),
    "reduce_sum": lambda ins, e: ins[0].scale(_reduce_count(e)),
    "reduce_max": _passthrough,
    "reduce_min": _passthrough,
    "reduce_and": lambda ins, e: Interval(0, 1),
    "reduce_or": lambda ins, e: Interval(0, 1),
    "iota": lambda ins, e: Interval(0, max(_nelem(e.outvars[0].aval) - 1, 0)),
    "rem": lambda ins, e: Interval(-ins[1].mag, ins[1].mag) if ins[1].bounded else TOP,
    "scatter-add": _scatter_add_transfer,
    "scatter": lambda ins, e: ins[0].union(ins[2]) if len(ins) >= 3 else TOP,
}

_CMP = ("eq", "ne", "lt", "le", "gt", "ge", "is_finite")


def _union_all(ivals: List[Interval]) -> Interval:
    out = ivals[0]
    for i in ivals[1:]:
        out = out.union(i)
    return out


def _closed(j):
    """(jaxpr, consts) from ClosedJaxpr | Jaxpr."""
    if hasattr(j, "jaxpr"):
        return j.jaxpr, list(j.consts)
    return j, []


class _Eval:
    def __init__(self, axis_sizes, prim_overrides, on_eqn, scan_cap):
        self.axis_sizes = dict(axis_sizes or {})
        self.overrides = dict(prim_overrides or {})
        self.on_eqn = on_eqn
        self.scan_cap = scan_cap

    # -- env helpers -----------------------------------------------------
    def read(self, env, atom) -> Interval:
        if hasattr(atom, "val"):  # Literal
            return Interval.from_value(atom.val)
        return env.get(id(atom), TOP)

    def bind(self, env, jaxpr, consts, in_ivals):
        for v, c in zip(jaxpr.constvars, consts):
            env[id(v)] = Interval.from_value(c)
        for v, i in zip(jaxpr.invars, in_ivals):
            env[id(v)] = i

    # -- collectives -----------------------------------------------------
    def _axis_prod(self, eqn) -> Optional[int]:
        n = 1
        for a in eqn_axes(eqn):
            if a not in self.axis_sizes:
                return None
            n *= self.axis_sizes[a]
        return n

    def _collective(self, eqn, ins) -> List[Interval]:
        name = eqn.primitive.name
        if name in ("psum", "psum_scatter", "reduce_scatter"):
            n = self._axis_prod(eqn)
            if n is None:
                return [TOP for _ in eqn.outvars]
            return [i.scale(n) for i in ins]
        if name == "pmean":
            return list(ins)
        # pmax/pmin/all_gather/ppermute/all_to_all: element values unchanged
        return list(ins[: len(eqn.outvars)]) or [TOP for _ in eqn.outvars]

    # -- structured control flow -----------------------------------------
    def _eval_scan(self, eqn, ins) -> List[Interval]:
        body, consts = _closed(eqn.params["jaxpr"])
        nc = eqn.params["num_consts"]
        nk = eqn.params["num_carry"]
        length = int(eqn.params.get("length", self.scan_cap + 1))
        cs, carry, xs = ins[:nc], ins[nc: nc + nk], ins[nc + nk:]
        n_ys = len(body.outvars) - nk
        ys = [None] * n_ys
        if length <= self.scan_cap:
            # exact unrolled evaluation — this is what derives the M-microbatch
            # integer accumulator bound [−M·lim, M·lim] instead of assuming it
            for _ in range(length):
                outs = self.eval(body, consts, cs + carry + xs)
                carry = outs[:nk]
                ys = [y if y2 is None else (y2 if y is None else y.union(y2))
                      for y, y2 in zip(outs[nk:], ys)]
            return carry + [y if y is not None else TOP for y in ys]
        # widen: iterate to fixpoint a few rounds, then TOP the unstable carries
        for _ in range(4):
            outs = self.eval(body, consts, cs + carry + xs)
            new_carry = [a.union(b) for a, b in zip(carry, outs[:nk])]
            if new_carry == carry:
                return carry + outs[nk:]
            carry = new_carry
        carry = [c if c == o else TOP
                 for c, o in zip(carry, self.eval(body, consts, cs + carry + xs)[:nk])]
        outs = self.eval(body, consts, cs + carry + xs)
        return carry + outs[nk:]

    def _eval_while(self, eqn, ins) -> List[Interval]:
        body, bconsts = _closed(eqn.params["body_jaxpr"])
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        bcs = ins[cn: cn + bn]
        carry = ins[cn + bn:]
        for _ in range(4):
            outs = self.eval(body, bconsts, bcs + carry)
            new_carry = [a.union(b) for a, b in zip(carry, outs)]
            if new_carry == carry:
                return carry
            carry = new_carry
        return [TOP] * len(carry)

    def _eval_cond(self, eqn, ins) -> List[Interval]:
        outs = None
        for br in eqn.params["branches"]:
            sub, consts = _closed(br)
            o = self.eval(sub, consts, ins[1:])
            outs = o if outs is None else [a.union(b) for a, b in zip(outs, o)]
        return outs if outs is not None else [TOP] * len(eqn.outvars)

    # -- generic call-style recursion ------------------------------------
    def _eval_call(self, eqn, ins) -> Optional[List[Interval]]:
        for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if k in eqn.params:
                sub, consts = _closed(eqn.params[k])
                if len(sub.invars) == len(ins) and len(sub.outvars) == len(eqn.outvars):
                    return self.eval(sub, consts, ins)
        return None

    # -- the interpreter loop --------------------------------------------
    def eval(self, jaxpr, consts, in_ivals) -> List[Interval]:
        env: Dict[int, Interval] = {}
        self.bind(env, jaxpr, consts, in_ivals)
        for eqn in jaxpr.eqns:
            ins = [self.read(env, a) for a in eqn.invars]
            name = eqn.primitive.name
            outs: Optional[List[Interval]] = None
            if name in self.overrides:
                outs = self.overrides[name](eqn, ins)
            if outs is None:
                if name in COLLECTIVES:
                    outs = self._collective(eqn, ins)
                elif name == "scan":
                    outs = self._eval_scan(eqn, ins)
                elif name == "while":
                    outs = self._eval_while(eqn, ins)
                elif name == "cond":
                    outs = self._eval_cond(eqn, ins)
                elif name == "optimization_barrier":
                    outs = list(ins)
                elif name == "top_k":
                    outs = _top_k_transfer(ins, eqn)
                elif name in _CMP:
                    outs = [Interval(0, 1) for _ in eqn.outvars]
                elif name in _TRANSFER:
                    outs = [_TRANSFER[name](ins, eqn)]
                else:
                    outs = self._eval_call(eqn, ins)
                    if outs is None:
                        outs = [TOP for _ in eqn.outvars]
            if len(outs) != len(eqn.outvars):
                outs = [TOP for _ in eqn.outvars]
            for v, o in zip(eqn.outvars, outs):
                env[id(v)] = o
            if self.on_eqn is not None:
                self.on_eqn(eqn, ins, outs)
        return [self.read(env, v) for v in jaxpr.outvars]


def eval_jaxpr_intervals(
    closed_jaxpr,
    in_ivals: Optional[List[Interval]] = None,
    *,
    axis_sizes: Optional[Dict[str, int]] = None,
    prim_overrides: Optional[Dict[str, Callable]] = None,
    on_eqn: Optional[Callable] = None,
    scan_cap: int = 8,
) -> List[Interval]:
    """Forward interval evaluation of a (Closed)Jaxpr.

    ``axis_sizes`` maps mesh axis names to sizes so psum-style collectives
    can scale soundly (unknown axes widen to TOP). ``prim_overrides`` maps a
    primitive name to ``fn(eqn, in_ivals) -> [out_ivals] | None`` — the wire
    auditor uses it to install the trusted encode-kernel contract for
    ``pallas_call``. ``on_eqn(eqn, in_ivals, out_ivals)`` observes every
    evaluated eqn (an eqn inside a scan body is observed once per unrolled
    iteration — observers union by eqn identity).
    """
    jaxpr, consts = _closed(closed_jaxpr)
    if in_ivals is None:
        in_ivals = [TOP] * len(jaxpr.invars)
    ev = _Eval(axis_sizes, prim_overrides, on_eqn, scan_cap)
    return ev.eval(jaxpr, consts, list(in_ivals))


# --------------------------------------------------------------------------
# codec-level chain proof
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChainProof:
    """Symbolic §5.1 proof for one declared wire configuration.

    Stage intervals are exact bounds on any run respecting the declared
    clip: `encode` one microbatch's image, `accum` the M-microbatch local
    accumulator, `packed_field` one worker's biased transport field
    (packed) or lane value (dense), `wire_partial` any j≤n partial sum a
    ring hop may carry, `wire_sum` the full n-worker field/lane sum, and
    `image_sum` the unpacked integer image. `violations` is non-empty iff
    the configuration can overflow/degenerate; each entry is
    ``(check_id, human message)``.
    """

    kind: str
    bits: int
    n_workers: int
    n_accum: int
    lim: int
    stages: Dict[str, Interval]
    violations: Tuple[Tuple[str, str], ...]

    @property
    def ok(self) -> bool:
        return not self.violations


def wire_chain_proof(
    kind: str,
    bits: int,
    n_workers: int,
    n_accum: int = 1,
    lim: Optional[int] = None,
) -> ChainProof:
    """Prove (or refute) the guard-bit invariant for one wire config.

    ``lim`` defaults to the declared §5.1 limit ``clip_limit(n·M)``; pass a
    clip bound observed in a traced jaxpr to check a *looser-than-declared*
    clip against the same overflow conditions (the forgot-``n_accum`` bug
    class fails here even though the declared config is fine).
    """
    if kind not in ("dense", "packed", "topk"):
        raise ValueError(f"unknown wire kind {kind!r}")
    n, M = int(n_workers), int(n_accum)
    R = int_range_max(bits)
    lim_declared = declared_clip_limit(kind, n * M, bits)
    L = lim_declared if lim is None else int(lim)
    bad: List[Tuple[str, str]] = []
    if L <= 0:
        bad.append((
            "degenerate-clip",
            f"clip limit (2^{bits - 1}-1)//{n * M} == 0 for {n} workers × "
            f"{M} microbatches on an int{bits} wire: every gradient entry "
            f"would be clipped to 0 (the WireRangeError condition)",
        ))
        L = 0

    encode = Interval(-L, L)
    accum = encode.scale(M)
    stages: Dict[str, Interval] = {"encode": encode, "accum": accum}

    if kind == "dense":
        # lane value is the accumulator itself; ring partials / the psum grow
        # it by up to n contributions, all of which must fit the lane range
        field = accum
        wire_sum = accum.scale(n)
        stages["packed_field"] = field
        stages["wire_partial"] = wire_sum  # j≤n partials ⊆ the n-worker hull
        stages["wire_sum"] = wire_sum
        lane_max = R if bits < 32 else _INT_RANGE[32]
        if wire_sum.mag > lane_max:
            bad.append((
                "lane-overflow",
                f"n-worker lane sum |Σ| ≤ {int(wire_sum.mag)} exceeds the "
                f"int{bits} lane range ±{lane_max} (clip |v| ≤ {L} is too "
                f"loose for {n} workers × {M} microbatches)",
            ))
    elif kind == "topk":
        # gather transport: every field crosses the wire UNSUMMED as a plain
        # two's-complement `bits`-wide value next to its int32 index — no
        # bias, no field-to-field addition, and no pipelined pre-pack
        # accumulation either (topk is never fused: each of the M images is
        # encoded fresh at ±L), so the field is the ENCODE stage and the
        # only field condition is that the (possibly observed) clip fits
        # the value width. Partial and full "wire sums" are the field
        # itself: the sum happens after transport, in the scatter-add
        # image checked below — which is where the n·M product bites.
        field = encode
        stages["packed_field"] = field
        stages["wire_partial"] = field
        stages["wire_sum"] = field
        if field.mag > R:
            bad.append((
                "field-overflow",
                f"topk value field |v| ≤ {int(field.mag)} exceeds the "
                f"int{bits} two's-complement range ±{R} (clip |v| ≤ {L} "
                f"is wider than the value plane)",
            ))
    else:
        # packed: pack() biases every field by clip_limit(n) (the bias the
        # unpack side subtracts n× of), while values are bounded by the
        # pipelined clip M·clip_limit(n·M) ≤ clip_limit(n)
        bias = safe_clip_limit(n, bits)
        field = accum.add(Interval.point(bias))
        wire_sum = field.scale(n)
        stages["packed_field"] = field
        stages["wire_partial"] = Interval(
            min(0, wire_sum.lo), max(0, wire_sum.hi)
        )  # a j-hop partial is j ≤ n biased fields; hull includes j=0
        stages["wire_sum"] = wire_sum
        if field.lo < 0:
            bad.append((
                "field-underflow",
                f"biased field v+{bias} can reach {int(field.lo)} < 0 "
                f"(clip |v| ≤ {L} with {M} microbatches exceeds the "
                f"pack bias clip_limit({n}) = {bias}): a negative field "
                f"borrows from its packed neighbour",
            ))
        if wire_sum.hi > (1 << bits) - 2:
            bad.append((
                "field-overflow",
                f"{n}-worker biased field sum can reach "
                f"{int(wire_sum.hi)} > 2^{bits}-2 = {(1 << bits) - 2}: the "
                f"field carries into its packed neighbour (clip |v| ≤ {L} "
                f"is too loose for {n} workers × {M} microbatches)",
            ))

    image = accum.scale(n)
    stages["image_sum"] = image
    if image.mag > _INT_RANGE[32]:
        bad.append((
            "image-overflow",
            f"summed integer image |Σ| ≤ {int(image.mag)} exceeds int32",
        ))
    return ChainProof(
        kind=kind, bits=bits, n_workers=n, n_accum=M,
        lim=L, stages=stages, violations=tuple(bad),
    )
