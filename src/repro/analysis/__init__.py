"""repro.analysis — static verification of the repo's contracts (PR 8/9).

Three layers:

  * the floatless-wire AUDITOR (``jaxpr_walk`` + ``intervals`` +
    ``wire_audit``): jaxpr-level proof that a built train step puts no
    float on the dp wire and that the §5.1 guard-bit/overflow invariants
    hold for the declared (codec, n_workers, microbatches) — W-rules;
  * the PERFORMANCE auditor (``schedule`` + ``traffic``, PR 9):
    dependence-graph proof that the wire collectives are overlap-eligible
    (P-rules + static roofline) and that their bytes/counts equal the
    declared transport model, i.e. exactly what the ``Logged`` codec meters
    (T-rules); ``schedule.full_audit`` composes all three families;
  * the AST contract LINTER (``lint``): C-rules over the source tree, no
    jax import anywhere on its path.

This ``__init__`` stays import-light on purpose: ``python -m
repro.analysis.lint src/`` must be able to run (and fail a CI job) before
anything imports jax. The audit API is re-exported lazily.

CLI: ``python -m repro.analysis --matrix [--check] [--diff]`` sweeps the
supported (config × codec × overlap × microbatch) grid, writes
``ANALYSIS_report.json`` + the ``ANALYSIS_roofline.json`` table, and with
``--diff`` fails on any drift against the committed report instead of
rewriting it.
"""
from __future__ import annotations

_LAZY = {
    "audit_jaxpr": "repro.analysis.wire_audit",
    "audit_step": "repro.analysis.wire_audit",
    "spec_for_step": "repro.analysis.wire_audit",
    "WireSpec": "repro.analysis.wire_audit",
    "Violation": "repro.analysis.wire_audit",
    "AuditReport": "repro.analysis.wire_audit",
    "WireAuditError": "repro.analysis.wire_audit",
    "RULES": "repro.analysis.wire_audit",
    "SCALAR_REDUCE_ALLOWANCE": "repro.analysis.wire_audit",
    "Interval": "repro.analysis.intervals",
    "wire_chain_proof": "repro.analysis.intervals",
    "eval_jaxpr_intervals": "repro.analysis.intervals",
    "iter_eqns": "repro.analysis.jaxpr_walk",
    "COLLECTIVES": "repro.analysis.jaxpr_walk",
    "build_graph": "repro.analysis.jaxpr_walk",
    "backward_eqns": "repro.analysis.jaxpr_walk",
    "forward_eqns": "repro.analysis.jaxpr_walk",
    "analyze_schedule": "repro.analysis.schedule",
    "full_audit": "repro.analysis.schedule",
    "verify_step": "repro.analysis.schedule",
    "ScheduleReport": "repro.analysis.schedule",
    "FullReport": "repro.analysis.schedule",
    "account_traffic": "repro.analysis.traffic",
    "plan_transport": "repro.analysis.traffic",
    "TransportPlan": "repro.analysis.traffic",
    "TrafficReport": "repro.analysis.traffic",
    "lint_paths": "repro.analysis.lint",
    "lint_source": "repro.analysis.lint",
    "LINT_RULES": "repro.analysis.lint",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
