"""repro.analysis — static verification of the repo's contracts (PR 8).

Two layers:

  * the floatless-wire AUDITOR (``jaxpr_walk`` + ``intervals`` +
    ``wire_audit``): jaxpr-level proof that a built train step puts no
    float on the dp wire and that the §5.1 guard-bit/overflow invariants
    hold for the declared (codec, n_workers, microbatches);
  * the AST contract LINTER (``lint``): C-rules over the source tree, no
    jax import anywhere on its path.

This ``__init__`` stays import-light on purpose: ``python -m
repro.analysis.lint src/`` must be able to run (and fail a CI job) before
anything imports jax. The audit API is re-exported lazily.

CLI: ``python -m repro.analysis --matrix [--check]`` sweeps the supported
(config × codec × overlap × microbatch) grid and writes
``ANALYSIS_report.json``.
"""
from __future__ import annotations

_LAZY = {
    "audit_jaxpr": "repro.analysis.wire_audit",
    "audit_step": "repro.analysis.wire_audit",
    "spec_for_step": "repro.analysis.wire_audit",
    "WireSpec": "repro.analysis.wire_audit",
    "Violation": "repro.analysis.wire_audit",
    "AuditReport": "repro.analysis.wire_audit",
    "WireAuditError": "repro.analysis.wire_audit",
    "RULES": "repro.analysis.wire_audit",
    "Interval": "repro.analysis.intervals",
    "wire_chain_proof": "repro.analysis.intervals",
    "eval_jaxpr_intervals": "repro.analysis.intervals",
    "iter_eqns": "repro.analysis.jaxpr_walk",
    "COLLECTIVES": "repro.analysis.jaxpr_walk",
    "lint_paths": "repro.analysis.lint",
    "lint_source": "repro.analysis.lint",
    "LINT_RULES": "repro.analysis.lint",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
