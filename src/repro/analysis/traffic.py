"""Static wire-traffic accountant: bytes and collective counts, proven.

PR 3/PR 4 pinned the wire's cheapness claims with runtime ``--check`` smokes
(count collectives on one debug mesh, meter bytes with the ``Logged`` codec).
This module makes the same numbers a THEOREM about the traced step: from the
:class:`~repro.analysis.wire_audit.WireSpec`'s transport declaration alone
(per-leaf image sizes, codec, overlap mode, bucket size) it reconstructs the
exact eqn-level transport the step must emit —

  * ``overlap="off"``:  one psum of the whole words tree per microbatch
    image, carrying exactly the codec payload
    (``Σ wire_bytes(leaf)`` — what ``Logged.pack_bytes`` meters per image
    and what ``BucketManifest.payload_bytes`` records);
  * ``overlap="ring"``: per image and bucket of size s, for every dp axis of
    size n > 1: (n-1) ppermute hops + 1 all_gather, each carrying a
    ⌈s/n⌉-word chunk (``ring_allreduce_int`` pads s to n·⌈s/n⌉; the padding
    is reported, not hidden) — a size-1 axis short-circuits in Python and
    emits nothing;
  * gather transport (``wire_transport="gather"``, TopKInt): per image the
    bucketized payload (one bucket serial; ``bucket_words`` cuts under ring
    overlap) rides one all_gather per dp axis of size > 1, operands
    COMPOUNDING across axes (the second axis gathers the first's stacked
    output) — exactly ``BucketManifest.gather_collectives``.

— then walks the jaxpr and demands the observed wire collectives match:

  T001  observed wire-collective BYTES ≠ the declared transport model's
        (payload drift: a codec re-encoding, an accidental widening, a
        bucketing change that inflates the wire);
  T002  observed wire-collective COUNT ≠ the declared transport model's
        (transport-shape drift: a fused/elided/duplicated collective — the
        static twin of bench_overlap's "12 bucketed vs 1 serial" gate).

The declared payload is BY CONSTRUCTION the number the runtime meters agree
on (``Logged`` calls the same ``wire_bytes`` arithmetic per pack;
``plan_buckets`` cuts the same word total), which tests/test_schedule.py
pins across every codec × n × M; T001/T002 then extend that equality to the
traced eqns, making BENCH_comm_volume/BENCH_overlap cross-checkable without
executing anything.

Wire-collective identification (shared with :mod:`repro.analysis.schedule`
and benchmarks/bench_overlap.py's runtime counter): a collective eqn over
any declared dp axis with an integer operand, of a kind that can carry the
transport ({psum, ppermute, all_gather, reduce_scatter, psum_scatter}).
Float collectives (loss/metric reductions, ZeRO-1 bf16 gathers) and
model-axis traffic are out of scope here — wire_audit's W001 owns them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis import jaxpr_walk as jw
from repro.analysis.wire_audit import Violation, WireSpec

__all__ = [
    "RULES",
    "WIRE_COLLECTIVE_PRIMS",
    "TransportPlan",
    "TrafficReport",
    "leaf_wire_words",
    "word_itemsize",
    "payload_bytes",
    "plan_bucket_sizes",
    "plan_transport",
    "wire_collective_eqns",
    "account_traffic",
]

RULES = {
    "T001": "observed wire-collective bytes equal the declared transport "
            "model's (codec payload + ring chunk padding)",
    "T002": "observed wire-collective count equals the declared transport "
            "model's (serial: 1 psum/image; ring: n_ax collectives per "
            "bucket per dp axis)",
}

# collective kinds that can carry transport words; gathers included because
# the ring's finished chunks ride an all_gather. pmax/pmin/all_to_all never
# carry the wire (metrics / MoE shuffles) and are excluded so they can't
# pollute the byte account.
WIRE_COLLECTIVE_PRIMS = frozenset(
    {"psum", "ppermute", "all_gather", "reduce_scatter", "psum_scatter"}
)


# ---------------------------------------------------------------------------
# declared-side arithmetic (jax-free: mirrors repro.wire without importing it)
# ---------------------------------------------------------------------------
def word_itemsize(kind: str, bits: int) -> int:
    """Transport word size in bytes: PackedInt and TopKInt always ride
    int32 words (topk: int32 index plane + bit-packed value words);
    DenseInt rides the narrowest native lane holding one value (mirrors
    repro.wire.dense._LANE)."""
    if kind in ("packed", "topk"):
        return 4
    return 1 if bits <= 8 else (2 if bits <= 16 else 4)


def leaf_wire_words(kind: str, bits: int, size: int, *, k: int = 0) -> int:
    """Transport words one leaf of ``size`` elements packs into (mirrors
    PackedInt.words_len / DenseInt's identity layout / TopKInt's
    idx-plane + bit-packed vals-plane split, all int32 words)."""
    if kind == "packed":
        f = 32 // bits
        return -(-int(size) // f)
    if kind == "topk":
        k_eff = min(int(k), int(size)) if k else int(size)
        f = 32 // bits
        return k_eff + -(-k_eff // f)
    return int(size)


def payload_bytes(kind: str, bits: int, size: int, *, k: int = 0) -> int:
    """Exact wire bytes for one leaf — equals ``WireFormat.wire_bytes(size)``
    and therefore what ``Logged`` meters per pack call."""
    return leaf_wire_words(kind, bits, size, k=k) * word_itemsize(kind, bits)


def plan_bucket_sizes(total_words: int, bucket_words: int) -> Tuple[int, ...]:
    """Bucket word counts for a ``total_words`` payload — the same cut as
    ``repro.wire.bucketing.plan_buckets`` (full buckets + ragged tail),
    kept jax-free here and pinned equal by tests/test_schedule.py."""
    if bucket_words <= 0:
        raise ValueError(f"bucket_words must be positive, got {bucket_words}")
    full, tail = divmod(int(total_words), int(bucket_words))
    return (bucket_words,) * full + ((tail,) if tail else ())


@dataclasses.dataclass(frozen=True)
class TransportPlan:
    """The eqn-level transport a spec declares, per STEP (all microbatch
    images)."""

    payload_bytes: int      # codec payload, one image (== Logged per image)
    total_words: int        # transport words, one image
    n_buckets: int          # 0 on the serial route
    n_eqns: int             # wire collectives the whole step must emit
    coll_bytes: int         # total operand bytes those eqns carry
    padding_bytes: int      # ring chunk padding included in coll_bytes
    by_prim: Dict[str, int]  # prim name -> eqn count (whole step)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["by_prim"] = dict(d["by_prim"])
        return d


def plan_transport(spec: WireSpec) -> Optional[TransportPlan]:
    """Reconstruct the declared transport from the spec alone — or None when
    the spec carries no leaf sizes (hand-built specs can't be accounted)."""
    if not spec.leaf_sizes:
        return None
    kind, bits = spec.wire_kind, spec.bits
    itemsize = word_itemsize(kind, bits)
    words = [
        leaf_wire_words(kind, bits, s, k=spec.topk_k) for s in spec.leaf_sizes
    ]
    total_words = sum(words)
    payload = total_words * itemsize
    by_prim: Dict[str, int] = {}
    if spec.wire_transport == "gather":
        # Gather route (CommCtx._gather_wire): the payload is always
        # bucketized — at bucket_words under ring overlap, as ONE bucket
        # otherwise — and each bucket rides one all_gather per dp axis of
        # size > 1 (size-1 axes short-circuit in allgather_wire_words and
        # emit nothing). The gathers COMPOUND: the second axis gathers the
        # first axis's already-stacked output, so its operand is n₁× the
        # bucket — the same arithmetic as BucketManifest.gather_collectives,
        # pinned equal by tests. No chunk padding: plan_buckets cuts a
        # ragged tail and the gather ships it as-is.
        if spec.overlap == "ring" and spec.bucket_words:
            buckets = plan_bucket_sizes(total_words, spec.bucket_words)
        else:
            buckets = (total_words,) if total_words else ()
        gather_axes = [n for n in spec.dp_sizes if n > 1]
        coll_words = 0
        eqns = 0
        for s in buckets:
            grown = s
            for n in reversed(gather_axes):
                coll_words += grown
                eqns += 1
                by_prim["all_gather"] = by_prim.get("all_gather", 0) + 1
                grown *= n
        return TransportPlan(
            payload_bytes=payload,
            total_words=total_words,
            n_buckets=len(buckets),
            n_eqns=eqns * spec.n_accum,
            coll_bytes=coll_words * itemsize * spec.n_accum,
            padding_bytes=0,
            by_prim={p: v * spec.n_accum for p, v in by_prim.items()},
        )
    if spec.overlap == "ring":
        buckets = plan_bucket_sizes(
            total_words, spec.bucket_words or total_words
        )
        ring_axes = [n for n in spec.dp_sizes if n > 1]
        coll_words = 0
        eqns = 0
        for s in buckets:
            for n in ring_axes:
                chunk = -(-s // n)
                coll_words += n * chunk  # (n-1) ppermute hops + 1 gather
                eqns += n
                by_prim["ppermute"] = by_prim.get("ppermute", 0) + (n - 1)
                by_prim["all_gather"] = by_prim.get("all_gather", 0) + 1
        padding = coll_words * itemsize - payload * len(ring_axes)
        plan = TransportPlan(
            payload_bytes=payload,
            total_words=total_words,
            n_buckets=len(buckets),
            n_eqns=eqns * spec.n_accum,
            coll_bytes=coll_words * itemsize * spec.n_accum,
            padding_bytes=padding * spec.n_accum,
            by_prim={k: v * spec.n_accum for k, v in by_prim.items()},
        )
    else:
        plan = TransportPlan(
            payload_bytes=payload,
            total_words=total_words,
            n_buckets=0,
            n_eqns=spec.n_accum,
            coll_bytes=payload * spec.n_accum,
            padding_bytes=0,
            by_prim={"psum": spec.n_accum},
        )
    return plan


# ---------------------------------------------------------------------------
# observed side: walk the jaxpr
# ---------------------------------------------------------------------------
def _int_operand_bytes(eqn) -> int:
    return sum(
        jw.aval_size_bytes(v.aval)
        for v in eqn.invars
        if hasattr(v, "aval")
        and getattr(v.aval, "dtype", None) is not None
        and v.aval.dtype.kind in ("i", "u")
    )


def wire_collective_eqns(jaxpr, dp_axes) -> List[Tuple[object, int]]:
    """``(eqn, multiplicity)`` for every wire collective in the tree: a
    WIRE_COLLECTIVE_PRIMS eqn over any dp axis with an integer operand."""
    dp = set(dp_axes)
    out = []
    for eqn, scale in jw.iter_eqns_scaled(jaxpr):
        if eqn.primitive.name not in WIRE_COLLECTIVE_PRIMS:
            continue
        if not (set(jw.eqn_axes(eqn)) & dp):
            continue
        if _int_operand_bytes(eqn) == 0:
            continue
        out.append((eqn, scale))
    return out


@dataclasses.dataclass
class TrafficReport:
    """Declared-vs-observed wire traffic for one traced step."""

    plan: Optional[TransportPlan]
    observed_eqns: int
    observed_bytes: int
    observed_by_prim: Dict[str, int]     # prim -> eqn count
    observed_bytes_by_prim: Dict[str, int]
    violations: Tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "declared": self.plan.to_dict() if self.plan else None,
            "observed_eqns": self.observed_eqns,
            "observed_bytes": self.observed_bytes,
            "observed_by_prim": dict(self.observed_by_prim),
            "observed_bytes_by_prim": dict(self.observed_bytes_by_prim),
            "violations": [v.to_dict() for v in self.violations],
            "ok": self.ok,
        }


def account_traffic(closed_jaxpr, spec: WireSpec) -> TrafficReport:
    """Tally the traced step's wire collectives and prove them equal to the
    spec's declared transport (T001 bytes, T002 counts)."""
    top = closed_jaxpr.jaxpr if hasattr(closed_jaxpr, "jaxpr") else closed_jaxpr
    by_prim: Dict[str, int] = {}
    bytes_by_prim: Dict[str, int] = {}
    n_eqns = 0
    n_bytes = 0
    for eqn, scale in wire_collective_eqns(top, spec.dp_axes):
        name = eqn.primitive.name
        b = _int_operand_bytes(eqn) * scale
        by_prim[name] = by_prim.get(name, 0) + scale
        bytes_by_prim[name] = bytes_by_prim.get(name, 0) + b
        n_eqns += scale
        n_bytes += b

    violations: List[Violation] = []
    plan = plan_transport(spec)
    if plan is not None:
        if n_bytes != plan.coll_bytes:
            violations.append(Violation(
                "T001",
                f"wire@{','.join(spec.dp_axes)}",
                f"observed wire-collective bytes {n_bytes} != declared "
                f"transport {plan.coll_bytes} (codec payload "
                f"{plan.payload_bytes} B/image × M={spec.n_accum}"
                + (f" + ring padding {plan.padding_bytes} B"
                   if plan.padding_bytes else "")
                + f"; per-prim observed {bytes_by_prim})",
            ))
        if n_eqns != plan.n_eqns:
            violations.append(Violation(
                "T002",
                f"wire@{','.join(spec.dp_axes)}",
                f"observed {n_eqns} wire collective(s) {by_prim} != declared "
                f"{plan.n_eqns} {plan.by_prim} "
                f"({spec.overlap} route, {plan.n_buckets or 'no'} bucket(s), "
                f"M={spec.n_accum})",
            ))
    return TrafficReport(
        plan=plan,
        observed_eqns=n_eqns,
        observed_bytes=n_bytes,
        observed_by_prim=by_prim,
        observed_bytes_by_prim=bytes_by_prim,
        violations=tuple(violations),
    )
