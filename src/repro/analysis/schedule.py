"""Static overlap-schedule analyzer: prove the wire is hideable on the jaxpr.

The PR 3 overlap design hides the integer all-reduce behind backward compute
(bucketed ppermute rings, microbatch pipelining); until PR 9 the only
evidence was bench_overlap counting collectives at runtime on one debug
mesh. This module proves the schedule STRUCTURALLY, per traced step: build
the cross-scope dataflow graph (:func:`repro.analysis.jaxpr_walk
.build_graph`), and for every wire collective c compute

  * ancestors(c)   — eqns whose values flow INTO c (its issue frontier);
  * descendants(c) — eqns consuming c's result (its completion frontier);

everything in neither set is UNORDERED with c: XLA's latency-hiding
scheduler is free to run it while c's hops are in flight. A collective is
**overlap-eligible** when unordered work exists — dot_general FLOPs
(``concurrent_flops`` > 0: the reduce can hide behind compute, e.g. another
microbatch's backward) or other wire transport (``concurrent_wire_bytes`` >
0: bucket k interleaves with bucket j) — and **serialized** otherwise (the
monolithic serial psum: every dot feeds it, nothing consumes until decode).

The static roofline aggregates this per step: of all wire bytes, which
fraction rides collectives with concurrent backward FLOPs
(``hidden_fraction``) or with ANY unordered work (``interleavable_fraction``),
plus total backward FLOPs and per-collective FLOPs/bytes — the numbers
ROADMAP item 3's roofline needs, derived without executing.

P-rules (schedule violations; W = wire_audit, T = traffic, C = lint):

  P001  pipelining structurally broken — a wire collective's RESULT feeds
        compute (a dot_general) that another wire collective depends on: the
        later image's backward cannot start until the earlier reduce lands,
        which serializes the exact overlap the microbatch pipeline promises
        (clean pipelines decode only after the last image's reduce is
        issued).
  P002  wasted wire work — a dead wire collective (result unreachable from
        the step outputs), a duplicate (identical operands/axes: same sum
        computed twice), or a redundant cast round-trip (dtype A -> B -> A)
        on the wire path.
  P003  fused-route HBM byte budget — generalizes W003's "image-sized int
        operand" to a per-eqn bytes model for BOTH codecs: each fused
        pallas_call may consume at most the codec's wire payload for its
        image (packed: 4·⌈d/k⌉ B; dense: d·lane B); an integer operand above
        that budget is an HBM round-trip the one-pass contract forbids.

:func:`full_audit` composes the W/P/T layers over ONE trace;
``build_train_step(verify="static")`` and the ``--matrix`` CLI run exactly
that.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.analysis import jaxpr_walk as jw
from repro.analysis import traffic as tr
from repro.analysis import wire_audit as wa
from repro.analysis.wire_audit import Violation, WireSpec

__all__ = [
    "RULES",
    "ScheduleReport",
    "FullReport",
    "analyze_schedule",
    "full_audit",
    "verify_step",
]

RULES = {
    "P001": "no wire collective's result feeds compute another wire "
            "collective depends on (microbatch pipelining stays structural)",
    "P002": "no dead/duplicate wire collectives, no redundant cast "
            "round-trips on the wire path",
    "P003": "fused pallas_call integer operands stay within the codec's "
            "per-image wire-payload byte budget (one HBM pass)",
}


def _dot_flops(eqn) -> float:
    """FLOPs of one dot_general: 2·batch·M·N·K (the jaxpr_cost convention,
    duplicated here because src/ must not import a benchmarks module)."""
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(
        s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb)
    )
    n = math.prod(
        s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb)
    )
    return 2.0 * batch * m * n * contract


@dataclasses.dataclass
class ScheduleReport:
    """Per-collective overlap classification + the static roofline."""

    collectives: List[dict]          # one row per wire collective
    n_wire_collectives: int
    n_serialized: int
    total_wire_bytes: int
    hideable_bytes: int              # on collectives with concurrent FLOPs
    interleavable_bytes: int         # on collectives with ANY unordered work
    backward_flops: float            # all dot_general FLOPs, scan-scaled
    violations: Tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def hidden_fraction(self) -> float:
        return self.hideable_bytes / self.total_wire_bytes if self.total_wire_bytes else 0.0

    @property
    def interleavable_fraction(self) -> float:
        return (
            self.interleavable_bytes / self.total_wire_bytes
            if self.total_wire_bytes else 0.0
        )

    def to_dict(self) -> dict:
        return {
            "collectives": list(self.collectives),
            "n_wire_collectives": self.n_wire_collectives,
            "n_serialized": self.n_serialized,
            "total_wire_bytes": self.total_wire_bytes,
            "hideable_bytes": self.hideable_bytes,
            "interleavable_bytes": self.interleavable_bytes,
            "hidden_fraction": round(self.hidden_fraction, 6),
            "interleavable_fraction": round(self.interleavable_fraction, 6),
            "backward_flops": self.backward_flops,
            "violations": [v.to_dict() for v in self.violations],
            "ok": self.ok,
        }


def _where(eqn, idx: int) -> str:
    a = eqn.invars[0].aval if eqn.invars else eqn.outvars[0].aval
    axes = ",".join(jw.eqn_axes(eqn))
    return f"{eqn.primitive.name}#{idx}@{axes} {a.dtype}{tuple(a.shape)}"


def analyze_schedule(closed_jaxpr, spec: WireSpec) -> ScheduleReport:
    """Classify every wire collective of a traced step as overlap-eligible
    or serialized, check P001/P002/P003, and derive the static roofline."""
    top = closed_jaxpr.jaxpr if hasattr(closed_jaxpr, "jaxpr") else closed_jaxpr
    # per-call-site precision matters here: the default "link" mode merges
    # every call site of a jax-cached utility body into one hub, ordering
    # all microbatches against all collectives and killing the concurrency
    # this analyzer exists to prove
    graph = jw.build_graph(closed_jaxpr, shared_bodies="opaque")

    # dot_general FLOPs with scan multiplicity (id -> flops)
    dot_flops: Dict[int, float] = {}
    total_flops = 0.0
    for eqn, scale in jw.iter_eqns_scaled(top):
        if eqn.primitive.name == "dot_general":
            f = _dot_flops(eqn) * scale
            dot_flops[id(eqn)] = dot_flops.get(id(eqn), 0.0) + f
            total_flops += f

    wire = tr.wire_collective_eqns(top, spec.dp_axes)
    anc: List[set] = []
    desc: List[set] = []
    for eqn, _scale in wire:
        anc.append(jw.backward_eqns(eqn.invars, graph))
        desc.append(jw.forward_eqns(eqn.outvars, graph))
    anc_union: set = set().union(*anc) if anc else set()

    violations: List[Violation] = []
    rows: List[dict] = []
    n_serialized = 0
    total_bytes = hideable = interleavable = 0
    wire_bytes = [tr._int_operand_bytes(e) * s for e, s in wire]

    for i, (eqn, _scale) in enumerate(wire):
        unordered = lambda j: (  # noqa: E731 — tiny local predicate
            id(wire[j][0]) not in anc[i] and id(wire[j][0]) not in desc[i]
        )
        conc_flops = sum(
            f for eid, f in dot_flops.items()
            if eid not in anc[i] and eid not in desc[i]
        )
        conc_wire = sum(
            wire_bytes[j] for j in range(len(wire)) if j != i and unordered(j)
        )
        eligible = conc_flops > 0 or conc_wire > 0
        b = wire_bytes[i]
        total_bytes += b
        if conc_flops > 0:
            hideable += b
        if eligible:
            interleavable += b
        else:
            n_serialized += 1
        rows.append({
            "where": _where(eqn, i),
            "bytes": b,
            "concurrent_flops": conc_flops,
            "concurrent_wire_bytes": conc_wire,
            "eligible": eligible,
        })
        # P001: result feeds compute an(other) wire collective waits on
        broken = [
            eid for eid in desc[i]
            if eid in dot_flops and eid in anc_union and eid not in anc[i]
        ]
        if broken:
            violations.append(Violation(
                "P001", _where(eqn, i),
                f"collective result feeds {len(broken)} dot_general eqn(s) "
                f"that another wire collective depends on — the later "
                f"image's backward stalls on this reduce; pipelining is "
                f"structurally broken (decode must happen after the last "
                f"image's reduce is issued)",
            ))

    # ---- P002: dead / duplicate collectives, cast round-trips -----------
    live = jw.backward_eqns(top.outvars, graph)
    seen: Dict[tuple, int] = {}
    for i, (eqn, _scale) in enumerate(wire):
        if id(eqn) not in live:
            violations.append(Violation(
                "P002", _where(eqn, i),
                "dead wire collective: its result never reaches the step "
                "outputs — wire bytes spent on nothing",
            ))
        key = (
            eqn.primitive.name,
            jw.eqn_axes(eqn),
            tuple(id(v) for v in eqn.invars if jw.is_var(v)),
            str(eqn.params.get("perm")),
        )
        if key in seen:
            violations.append(Violation(
                "P002", _where(eqn, i),
                f"duplicate wire collective: identical operands and axes as "
                f"collective #{seen[key]} — the same sum crosses the wire "
                f"twice",
            ))
        else:
            seen[key] = i

    # cast round-trips on the wire path (upstream of reducing dp operands)
    wire_roots = []
    for eqn, _scale in wire:
        if eqn.primitive.name in jw.REDUCING_COLLECTIVES:
            wire_roots.extend(
                v for v in eqn.invars
                if jw.is_var(v)
                and getattr(v.aval, "dtype", None) is not None
                and v.aval.dtype.kind in ("i", "u")
            )
    if wire_roots:
        upstream = wa.backward_wire_eqns(wire_roots, graph)
        for eqn, _scale in jw.iter_eqns_scaled(top):
            if (eqn.primitive.name != "convert_element_type"
                    or id(eqn) not in upstream):
                continue
            src = eqn.invars[0]
            if not jw.is_var(src):
                continue
            e1 = graph.defs.get(id(src))
            if (e1 is None or id(e1) not in upstream
                    or e1.primitive.name != "convert_element_type"):
                continue
            d0 = e1.invars[0].aval.dtype
            d1 = src.aval.dtype
            d2 = eqn.outvars[0].aval.dtype
            # integer round-trips only: the transport is integer, and float
            # cast chains upstream (f32 -> bf16 compute -> f32 grads) are
            # the mixed-precision recipe, not wasted wire work
            if (d0 == d2 and d1 != d0
                    and all(d.kind in ("i", "u") for d in (d0, d1, d2))):
                violations.append(Violation(
                    "P002",
                    f"convert_element_type {d0}->{d1}->{d2}",
                    "redundant cast round-trip on the wire path: the value "
                    "returns to its original dtype (dead weight if "
                    "lossless, a truncation bug if not)",
                ))

    # ---- P003: fused-route per-eqn HBM byte budget -----------------------
    if spec.fused:
        for eqn, _scale in jw.iter_eqns_scaled(top):
            if eqn.primitive.name != "pallas_call":
                continue
            image = max(
                (jw.aval_nelem(v.aval)
                 for v in list(eqn.invars) + list(eqn.outvars)
                 if getattr(v.aval, "dtype", None) is not None
                 and v.aval.dtype.kind == "f"),
                default=0,
            )
            if not image:
                continue
            budget = tr.payload_bytes(spec.wire_kind, spec.bits, image)
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if (aval is None or getattr(aval, "dtype", None) is None
                        or aval.dtype.kind not in ("i", "u")):
                    continue
                if jw.aval_nelem(aval) <= spec.scalar_allowance:
                    continue  # step counters / scalar state
                b = jw.aval_size_bytes(aval)
                if b > budget:
                    violations.append(Violation(
                        "P003",
                        f"pallas_call {aval.dtype}{tuple(aval.shape)}",
                        f"integer kernel operand of {b} B exceeds the "
                        f"{spec.wire_kind}{spec.bits} wire-payload budget "
                        f"{budget} B for its {image}-element image — an "
                        f"HBM round-trip the one-pass fused route forbids",
                    ))

    return ScheduleReport(
        collectives=rows,
        n_wire_collectives=sum(s for _e, s in wire),
        n_serialized=n_serialized,
        total_wire_bytes=total_bytes,
        hideable_bytes=hideable,
        interleavable_bytes=interleavable,
        backward_flops=total_flops,
        violations=tuple(violations),
    )


# ---------------------------------------------------------------------------
# the composed W + P + T audit
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FullReport:
    """One trace, all three static layers: wire audit (W), schedule (P),
    traffic (T). ``violations`` merges the kept violations of every layer;
    suppression spans all of them (rule ids are disjoint by prefix)."""

    audit: wa.AuditReport
    schedule: ScheduleReport
    traffic: tr.TrafficReport
    suppressed: Tuple[Tuple[Violation, str], ...]

    @property
    def violations(self) -> Tuple[Violation, ...]:
        return (
            self.audit.violations
            + self.schedule.violations
            + self.traffic.violations
        )

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_failed(self):
        if not self.ok:
            lines = "\n".join(f"  {v}" for v in self.violations)
            raise wa.WireAuditError(
                f"static audit failed "
                f"({len(self.violations)} violation(s)):\n{lines}"
            )

    def to_dict(self) -> dict:
        d = self.audit.to_dict()
        d["violations"] = [v.to_dict() for v in self.violations]
        d["suppressed"] = [
            {**v.to_dict(), "justification": j}
            for v, j in self.audit.suppressed + self.suppressed
        ]
        d["schedule"] = self.schedule.to_dict()
        d["traffic"] = self.traffic.to_dict()
        d["ok"] = self.ok
        return d


def full_audit(
    closed_jaxpr,
    spec: WireSpec,
    *,
    suppress: Optional[Dict[str, str]] = None,
) -> FullReport:
    """Run the W (wire), P (schedule) and T (traffic) rule families over one
    traced step. ``suppress`` may waive any rule id, W/P/T alike."""
    suppress = dict(suppress or {})
    known = {**wa.RULES, **RULES, **tr.RULES}
    for rule, why in suppress.items():
        if rule not in known:
            raise ValueError(f"unknown rule {rule!r} in suppress")
        if not str(why).strip():
            raise ValueError(
                f"suppressing {rule} requires a non-empty justification"
            )
    w_suppress = {r: j for r, j in suppress.items() if r in wa.RULES}
    audit = wa.audit_jaxpr(closed_jaxpr, spec, suppress=w_suppress)
    schedule = analyze_schedule(closed_jaxpr, spec)
    traffic = tr.account_traffic(closed_jaxpr, spec)

    suppressed: List[Tuple[Violation, str]] = []

    def keep(report):
        kept = []
        for v in report.violations:
            if v.rule in suppress:
                suppressed.append((v, suppress[v.rule]))
            else:
                kept.append(v)
        report.violations = tuple(kept)

    keep(schedule)
    keep(traffic)
    return FullReport(
        audit=audit,
        schedule=schedule,
        traffic=traffic,
        suppressed=tuple(suppressed),
    )


def verify_step(artifacts, which: str = "compressed", **kw) -> FullReport:
    """Trace one jitted variant of a built step and run the full W/P/T
    static audit against its attached spec — what
    ``build_train_step(verify="static")`` executes."""
    import jax  # deferred: the lint half of repro.analysis is jax-free

    spec = getattr(artifacts, "audit_spec", None)
    if spec is None:
        raise ValueError(
            "StepArtifacts carries no audit_spec — build the step with "
            "repro.launch.step.build_train_step (PR 8+) or pass full_audit "
            "an explicit WireSpec"
        )
    jaxpr = jax.make_jaxpr(artifacts.jitted[which])(*artifacts.arg_structs)
    return full_audit(jaxpr, spec, **kw)
