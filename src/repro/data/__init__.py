from repro.data.synthetic import SyntheticLMData, worker_batches
from repro.data.logreg import LogRegProblem, make_logreg
