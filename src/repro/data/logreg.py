"""ℓ2-regularized logistic regression (paper Appendix C.5).

Synthetic stand-in for the LibSVM datasets (offline container): features with
controllable heterogeneity across workers — the regime where plain IntGD's
max transmitted integer blows up and IntDIANA fixes it (Fig. 6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    A: jnp.ndarray  # (n_workers, m, d)
    b: jnp.ndarray  # (n_workers, m) in {-1, +1}
    lam: float

    @property
    def n_workers(self):
        return self.A.shape[0]

    def full_loss(self, x):
        logits = jnp.einsum("wmd,d->wm", self.A, x) * self.b
        return jnp.mean(jax.nn.softplus(-logits)) + 0.5 * self.lam * jnp.sum(x * x)

    def worker_loss(self, x, batch):
        """batch: {"A": (m', d), "b": (m',)} — one worker's (mini)batch."""
        logits = batch["A"] @ x["x"] * batch["b"]
        return jnp.mean(jax.nn.softplus(-logits)) + 0.5 * self.lam * jnp.sum(
            x["x"] * x["x"]
        )

    def worker_data(self):
        return {"A": self.A, "b": self.b}  # leading worker axis


def make_logreg(
    key, *, n_workers=12, m=128, d=300, lam=1e-4, heterogeneity=1.0
) -> LogRegProblem:
    """heterogeneity: 0 = iid splits; 1 = per-worker shifted feature means
    (the paper's sort-by-index split analogue)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x_true = jax.random.normal(k1, (d,)) / jnp.sqrt(d)
    shifts = heterogeneity * jax.random.normal(k2, (n_workers, 1, d))
    A = jax.random.normal(k3, (n_workers, m, d)) + shifts
    logits = jnp.einsum("wmd,d->wm", A, x_true)
    noise = 0.5 * jax.random.normal(k4, (n_workers, m))
    b = jnp.sign(logits + noise)
    b = jnp.where(b == 0, 1.0, b)
    return LogRegProblem(A=A, b=b, lam=lam)
