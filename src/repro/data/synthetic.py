"""Deterministic synthetic token pipeline.

Production shape: an infinite, deterministically seeded, shardable stream —
each data-parallel worker pulls its own slice by (step, worker_index), so
restarts and elastic re-meshes replay identical data without coordination
(the same property a real corpus loader gets from index-based sharding).

The token process is a Zipf-ish unigram mixture with a Markov flavor so the
loss curve is non-trivial (learnable structure + irreducible entropy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    batch_per_worker: int
    seed: int = 0

    def batch(self, step: int, worker: int):
        """Deterministic (tokens, labels) for (step, worker)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), worker
        )
        k1, k2 = jax.random.split(key)
        b, t, v = self.batch_per_worker, self.seq_len, self.vocab
        # zipf-ish marginals
        base = jax.random.randint(k1, (b, t), 0, v)
        skew = jnp.square(jax.random.uniform(k2, (b, t)))
        toks = (base * skew).astype(jnp.int32) % v
        # markov structure: every other token correlates with its predecessor
        shifted = jnp.roll(toks, 1, axis=1)
        mask = (jnp.arange(t) % 2).astype(bool)
        toks = jnp.where(mask[None, :], (shifted * 31 + 7) % v, toks)
        labels = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
        return {"tokens": toks, "labels": labels}


def worker_batches(data: SyntheticLMData, step: int, n_workers: int):
    """Stacked (n_workers, ...) batches for the vmap simulation trainer."""
    bs = [data.batch(step, w) for w in range(n_workers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
