"""Elastic scaling: re-mesh after node failures and keep training.

The property that makes IntSGD *elastic-friendly* (and that a fixed-α scheme
like Heuristic IntSGD lacks): the scaling rule α_k = √d / √(2 n r_k/η² + ε²)
takes the worker count n as an INPUT. When a data-parallel replica dies we
rebuild the mesh with n' = n - failed, recompute α with n', and the
convergence guarantees keep holding for the new n' (the theory never pins n).

Protocol (driver-level, single coordinator):
  1. failure detector flags dead hosts (heartbeat timeout in production;
     injected in tests);
  2. pick the largest (dp', tp) grid covering the surviving hosts, dropping
     at most dp_step replicas — TP groups are rebuilt whole: a TP group with
     any dead member is retired entirely;
  3. restore the latest checkpoint with the new mesh's shardings
     (CheckpointStore.restore is mesh-agnostic);
  4. rebuild the jitted step for the new mesh; rescale per-worker batch or
     accept the smaller global batch (configurable policy);
  5. resume from the checkpointed step (the data pipeline is indexed by
     (step, worker) so no data is skipped or repeated).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_dp: int  # surviving data-parallel replicas
    tp: int  # tensor-parallel degree (unchanged)
    retired_replicas: tuple  # dp indices dropped
    global_batch: int
    note: str


def plan_after_failures(
    *,
    dp: int,
    tp: int,
    failed_devices: Sequence[int],
    global_batch: int,
    keep_global_batch: bool = True,
    wire=None,
    microbatches: int = 1,
) -> ElasticPlan:
    """Devices are numbered dp-major: device = dp_index * tp + tp_index.
    A dp replica survives iff ALL of its tp members survive.

    ``wire`` (codec name or WireFormat) re-validates the wire configuration
    for the NEW worker count at plan time: the §5.1 clip limit
    ``(2^(bits-1)-1) // n`` depends on n, so growing back after failures (or
    a paradoxical shrink across a power-of-two boundary) can cross into the
    degenerate range where every integer clips to 0. Without this check the
    :class:`~repro.wire.base.WireRangeError` only fires at TRACE time, deep
    inside the rebuilt step — after the checkpoint restore and re-mesh work
    is already done. Validating here fails (or warns via ``note``) before
    any of that starts.

    ``microbatches`` must match the rebuilt step's setting: with M-microbatch
    pipelining the step encodes with ``clip_limit(n_dp·M)``
    (``IntSGD.encode_ints(n_accum=M)``), so THAT is the product that must
    stay representable — and keep_global_batch re-meshes typically RAISE M
    to fit the bigger per-worker batch, pushing toward the boundary.
    """
    failed = set(failed_devices)
    retired = tuple(
        r for r in range(dp) if any(r * tp + t in failed for t in range(tp))
    )
    n_dp = dp - len(retired)
    if n_dp <= 0:
        raise RuntimeError("no complete TP group survives; cold restart required")
    if keep_global_batch:
        # keep the optimization trajectory: same global batch, bigger
        # per-worker microbatch (grad-accum if it no longer fits)
        gb = global_batch
        note = f"global batch kept at {gb}; per-worker batch x{dp}/{n_dp}"
    else:
        gb = global_batch * n_dp // dp
        note = f"global batch rescaled {global_batch}->{gb}; lr should scale by {n_dp}/{dp}"
    if wire is not None:
        from repro.wire import WireRangeError, make_wire_format

        wf = make_wire_format(wire)
        mb = f" x{microbatches} microbatches" if microbatches > 1 else ""
        if getattr(wf, "transport", "psum") == "gather":
            # A gather-transport codec (TopKInt) never divides its clip by
            # n, so clip_limit cannot degenerate — the n-dependent bound
            # moved to the DECODE side: unpack scatter-adds up to n_dp·M
            # full-range values per coordinate into an int32 image. k is
            # per-leaf and mesh-independent, but the gathered payload and
            # the image sum both scale with the surviving worker count, so
            # re-prove the bound here, at plan time, like the psum clip.
            lim = wf.clip_limit(n_dp * microbatches)
            worst = n_dp * microbatches * lim
            int32_max = 2**31 - 1
            if worst > int32_max:
                raise WireRangeError(
                    f"gather wire {wf.name}{wf.bits} cannot decode over "
                    f"{n_dp} workers{mb}: scatter-added image sum can reach "
                    f"{worst} > int32 max {int32_max}"
                )
            note += (
                f"; wire {wf.name}{wf.bits}:{wf.k} revalidated for "
                f"n_dp'={n_dp}{mb} (decoded image sum |Σ| <= {worst} fits "
                f"int32; k={wf.k} per leaf intact)"
            )
        else:
            # raises WireRangeError at PLAN time if int{bits} cannot carry
            # the accumulated sum over the surviving n_dp workers x M
            # microbatches
            lim_new = wf.clip_limit(n_dp * microbatches)
            try:
                lim_old = wf.clip_limit(dp * microbatches)
                delta = f"clip limit {lim_old}->{lim_new}"
            except Exception:  # the OLD count was itself out of range
                delta = f"clip limit ->{lim_new} (previous n_dp={dp} was invalid)"
            note += (
                f"; wire {wf.name}{wf.bits} revalidated for n_dp'={n_dp}{mb} "
                f"({delta})"
            )
    return ElasticPlan(
        n_dp=n_dp, tp=tp, retired_replicas=retired, global_batch=gb, note=note
    )
