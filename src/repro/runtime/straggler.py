"""Straggler mitigation via partial integer aggregation.

Because IntSGD's wire format is a plain SUM of integers, dropping the k
slowest workers is algebraically trivial: sum the arrived integers and
divide by n_live·α instead of n·α. The resulting estimator is still an
unbiased (sub)gradient of the average over *contributing* workers — under
iid data this is the same objective; under heterogeneous data it introduces
the usual sampled-worker variance (same trade-off as client sampling in
federated learning).

Contrast: PowerSGD's two-phase P/Q all-reduces and QSGD's all-gather cannot
drop a late worker without restarting the collective — the sum-of-ints
contract is what buys this.

The partial sum goes over the WIRE CODEC, not the raw integer tree: a late
worker is modelled as sending the codec's encoding of the all-zeros image.
Zero-masking is NOT "identical on-the-wire math" for every codec — for
:class:`~repro.wire.packed.PackedInt` each field carries the bias-shifted
``v + lim``, so a masked worker's word is the pure bias pattern
``Σ_j lim << j·bits``, not the zero word. Unpacking the n-worker word sum
with ``n_summed = n`` subtracts exactly ``n·lim`` per field — the dead
workers' bias included — which is the alive-aware bias correction that makes
the masked contribution exactly zero post-unpack (pinned by the property
tests in tests/test_runtime.py). Skipping the codec (the pre-PR-3 behavior)
silently diverged under PackedInt: the raw-tree psum missed the bias
accounting and the decode divided a full-bias sum by n_live.

In production the timeout lives in the collective runtime; here we model it
as a mask so the policy is testable: `straggler_tolerant_sum` is the exact
aggregation rule the paper's Algorithm 1 line 12 degrades to under loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import collectives as coll

from repro.core.comm import CommCtx
from repro.wire import DenseInt, WireFormat


def straggler_tolerant_sum(
    ints_tree, alive: jax.Array, ctx: CommCtx, wf: WireFormat | None = None
):
    """Partial integer aggregation over the wire codec.

    ``ints_tree``: this worker's Int(α∘g) payload (the §5.1-clipped integer
    image); ``alive``: bool scalar (did this worker make the deadline);
    ``wf``: the wire codec the payload rides (defaults to the int32 dense
    transport). Returns ``(sum over alive workers, n_live)``.

    A late worker's image is zero-masked BEFORE pack, so what it puts on the
    wire is ``wf.pack(0)`` — for PackedInt the pure guard-bit bias word,
    whose contribution ``unpack(..., n_summed=ctx.n)`` subtracts exactly
    (every one of the n workers' bias terms entered the word sum, alive or
    not). For a gather-transport codec (TopKInt) the masked image's top-k
    selects zero values at indices 0..k-1 — a well-formed, non-empty payload
    whose scatter-add contributes exactly nothing, so the partial decode is
    bit-exact without special-casing the dead worker's index plane. The
    transport stays structurally floatless either way: it routes through
    ``CommCtx.psum_wire``, which dispatches on the codec's declared
    collective shape like every other wire aggregation.
    """
    wf = DenseInt(bits=32) if wf is None else wf
    a = alive.astype(jnp.int32)
    masked = jax.tree.map(lambda v: v * a, ints_tree)
    _, int_sum = ctx.psum_wire(masked, wf)
    n_live = coll.psum(a, ctx.axes)
    return int_sum, n_live


def decode_partial(int_sum_tree, alphas, n_live):
    """ghat = (1/(n_live·α_l)) Σ_alive Int(α_l g_i) per leaf.

    ``alphas`` is either a scalar α (Algorithm 1) or a per-leaf α tree
    matching ``int_sum_tree`` (Algorithm 2's blockwise rule) — a tree must
    NOT be broadcast through a scalar formula, each leaf divides by its own
    α. Returns ``(ghat_tree, all_dead)``: when every worker missed the
    deadline (``n_live == 0``) there is NO gradient information, and a
    silent zero decode would freeze training invisibly — the ``all_dead``
    bool flag surfaces it so the driver can skip the step / re-run the
    round, while the division stays finite via the max(n_live, 1) guard.
    """
    if jax.tree.structure(alphas) != jax.tree.structure(int_sum_tree):
        alphas = jax.tree.map(lambda _: alphas, int_sum_tree)
    all_dead = n_live == 0
    denom = jnp.maximum(n_live, 1).astype(jnp.float32)
    ghat = jax.tree.map(
        lambda s, a: s.astype(jnp.float32) / (denom * a),
        int_sum_tree,
        alphas,
    )
    return ghat, all_dead
