"""Straggler mitigation via partial integer aggregation.

Because IntSGD's wire format is a plain SUM of integers, dropping the k
slowest workers is algebraically trivial: sum the arrived integers and
divide by n_live·α instead of n·α. The resulting estimator is still an
unbiased (sub)gradient of the average over *contributing* workers — under
iid data this is the same objective; under heterogeneous data it introduces
the usual sampled-worker variance (same trade-off as client sampling in
federated learning).

Contrast: PowerSGD's two-phase P/Q all-reduces and QSGD's all-gather cannot
drop a late worker without restarting the collective — the sum-of-ints
contract is what buys this.

In production the timeout lives in the collective runtime; here we model it
as a mask so the policy is testable: `straggler_tolerant_sum` is the exact
aggregation rule the paper's Algorithm 1 line 12 degrades to under loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import CommCtx


def straggler_tolerant_sum(ints_tree, alive: jax.Array, ctx: CommCtx):
    """ints_tree: this worker's Int(α∘g) payload; alive: bool scalar (did
    this worker make the deadline). Returns (sum over alive workers,
    n_live). Late workers contribute zeros — identical on-the-wire math to
    the switch simply not adding their packets."""
    a = alive.astype(jnp.int32)
    masked = jax.tree.map(lambda v: v * a, ints_tree)
    int_sum = ctx.psum(masked)
    n_live = lax.psum(a, ctx.axes)
    return int_sum, n_live


def decode_partial(int_sum_tree, alpha, n_live):
    """ghat = (1/(n_live·α)) Σ_alive Int(α g_i)."""
    scale = 1.0 / (jnp.maximum(n_live, 1).astype(jnp.float32))
    return jax.tree.map(
        lambda s: s.astype(jnp.float32) * scale / alpha, int_sum_tree
    )
