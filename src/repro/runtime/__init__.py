from repro.runtime.elastic import ElasticPlan, plan_after_failures
from repro.runtime.straggler import straggler_tolerant_sum
