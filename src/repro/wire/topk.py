"""TopKInt — sparse integer wire: top-k value plane + index plane.

The dense codecs spend one `bits`-wide field per coordinate, so packed8's 4×
is their floor. The paper's adaptive α drives most integer fields to zero,
which is exactly where sparsification pays: keep only the k
largest-magnitude integers per leaf and ship them as TWO planes —

    vals : k two's-complement `bits`-wide fields packed into int32 words
    idx  : k int32 flat coordinates positioning them

A value is only meaningful next to its index, so no cross-worker sum may
happen on the wire: the payload rides the gather transport
(``transport = "gather"``), every worker's planes arrive intact, and
:meth:`unpack` performs the sum itself by scatter-adding each worker's
contribution into a dense int32 image. Three consequences fall out:

* The §5.1 clip no longer divides by n — :meth:`clip_limit` returns the full
  signed range of the value width. The decode-side image sum n·M·lim must
  fit int32 instead, which is the ``image-overflow`` check of the "topk"
  :func:`repro.analysis.intervals.wire_chain_proof` kind.
* Value fields carry plain two's complement (no guard-bit bias): nothing is
  ever added field-to-field in the packed representation, so sign-extension
  on unpack is exact for any clipped value.
* A dead worker's masked (all-zero) image selects zero values at indices
  0..k-1 — its scatter-add contributes exactly nothing, so the straggler
  route decodes bit-exactly without special-casing the empty payload.

Selection is deterministic: ``lax.top_k`` on |ints| breaks ties toward the
lower flat index (pinned by tests/test_topk.py), so every worker, every
re-trace, and the error-feedback residual all agree on the mask.

Dropping coordinates is lossy; compressors compensate with an EF21-style
error-feedback residual (see ``IntSGD``), computed against
:meth:`local_image` — the same selection pack performs, kept as an explicit
method so the residual never needs to unpack its own payload.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Tuple

import jax
import jax.numpy as jnp

from .base import WireFormat, _INT_RANGE

__all__ = ["TopKInt"]

_ALLOWED_BITS = (8, 16)


@dataclasses.dataclass(frozen=True)
class TopKInt(WireFormat):
    """Top-k sparse codec: ``k`` per-leaf survivors on a gather wire."""

    name: ClassVar[str] = "topk"
    transport: ClassVar[str] = "gather"
    plane_names: ClassVar[Tuple[str, ...]] = ("idx", "vals")
    fused_capable: ClassVar[bool] = False  # no fused scatter-decode kernel

    bits: int = 8
    k: int = 64

    def __post_init__(self):
        if self.bits not in _ALLOWED_BITS:
            raise ValueError(
                f"topk packs {self.bits}-bit values into int32 words; "
                f"supported widths are {_ALLOWED_BITS}"
            )
        if self.k < 1:
            raise ValueError(f"topk needs k >= 1, got {self.k}")

    # ---- static geometry ------------------------------------------------
    @property
    def fields(self) -> int:
        """Value fields per int32 word of the vals plane."""
        return 32 // self.bits

    def k_eff(self, size: int) -> int:
        """Survivors for a `size`-coordinate leaf: min(k, size), so small
        leaves (biases, norms) never pay for phantom coordinates."""
        return min(self.k, int(size))

    # ---- value stages ---------------------------------------------------
    def clip_limit(self, n_workers: int) -> int:
        """Full signed range of the value width: the gather wire carries no
        cross-worker sum, so nothing divides by n. The decode-side image sum
        (≤ n·M·lim per coordinate) is bounded by the chain proof instead."""
        del n_workers
        return _INT_RANGE[self.bits]

    def encode(self, x, alpha, key, *, n_workers, stochastic=True):
        """Int(α ∘ x) clipped at the FULL value range (see clip_limit).

        Always the jnp path: the Pallas ``int_compress`` kernel bakes in the
        psum-shaped n-divided clip, which would needlessly narrow the sparse
        wire's values; selection (top_k) dominates the encode cost anyway.
        """
        lim = self.clip_limit(n_workers)
        from repro.core import rounding  # lazy: core imports this package

        r = rounding.int_round(
            x.astype(jnp.float32) * alpha, key, stochastic=stochastic
        )
        return jnp.clip(r, -lim, lim).astype(jnp.int32)

    # ---- transport stages -----------------------------------------------
    def _select(self, ints: jax.Array):
        """Deterministic top-k by |value|: (idx, vals), ties -> lower index."""
        flat = ints.reshape(-1).astype(jnp.int32)
        k = self.k_eff(flat.size)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = idx.astype(jnp.int32)
        return idx, flat[idx]

    def _pack_vals(self, vals: jax.Array) -> jax.Array:
        """k clipped values -> ⌈k/fields⌉ int32 words, plain two's
        complement fields (no bias: nothing sums field-to-field)."""
        m, b = self.fields, self.bits
        k = vals.size
        words_len = -(-k // m)
        mask = (1 << b) - 1
        padded = jnp.zeros((words_len * m,), jnp.int32).at[:k].set(vals & mask)
        chunks = padded.reshape(m, words_len)
        word = jnp.zeros((words_len,), jnp.int32)
        for j in range(m):
            word = word | (chunks[j] << (j * b))
        return word

    def _unpack_vals(self, words: jax.Array, k: int) -> jax.Array:
        """Inverse of _pack_vals over a leading batch axis: (..., W) int32
        words -> (..., k) sign-extended int32 values."""
        m, b = self.fields, self.bits
        mask = (1 << b) - 1
        sign = 1 << (b - 1)
        cols = [(words >> (j * b)) & mask for j in range(m)]
        fields = jnp.concatenate(cols, axis=-1)
        return ((fields ^ sign) - sign)[..., :k]

    def pack(self, ints: jax.Array, *, n_workers: int):
        del n_workers  # selection is per-worker; nothing sums on the wire
        idx, vals = self._select(ints)
        return {"idx": idx, "vals": self._pack_vals(vals)}

    def unpack(self, payload, shape: Tuple[int, ...], *, n_summed: int):
        """Gathered payload (planes carry a leading ``n_summed`` worker
        axis) -> summed integer image, by scatter-add of every worker's
        sign-extended values at its own indices."""
        size = int(math.prod(shape)) if shape else 1
        k = self.k_eff(size)
        idx = payload["idx"].reshape(n_summed * k)
        words = payload["vals"].reshape(n_summed, -1)
        vals = self._unpack_vals(words, k).reshape(n_summed * k)
        out = jnp.zeros((size,), jnp.int32).at[idx].add(vals)
        return out.reshape(shape)

    def local_image(self, ints: jax.Array, *, n_workers: int) -> jax.Array:
        """The top-k-masked image this worker's payload decodes to — exact
        (pack's two's-complement fields are lossless for clipped values), so
        the EF residual sees precisely what the wire dropped."""
        del n_workers
        flat = ints.reshape(-1).astype(jnp.int32)
        idx, vals = self._select(ints)
        return jnp.zeros_like(flat).at[idx].set(vals).reshape(ints.shape)

    def wire_bytes(self, size: int) -> int:
        k = self.k_eff(size)
        return 4 * (-(-k // self.fields)) + 4 * k

    def fused_update(self, words, param, opt, scalars, *, kernel, n_summed,
                     shift=None):
        raise NotImplementedError(
            "topk has no fused decode+update kernel: the gather payload "
            "(vals + idx planes) needs a scatter-shaped decode the fused "
            "Pallas route does not implement (fused_capable is False); "
            "run with fused=False"
        )
