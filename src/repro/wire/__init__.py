"""Pluggable wire-codec subsystem: everything between rounded integers and
the psum. See :mod:`repro.wire.base` for the WireFormat contract.

Registry names accepted everywhere a codec can be configured
(``make_compressor(..., wire=...)``, ``build_train_step(..., wire=...)``,
``repro.launch.train --wire``):

    dense4 / dense8 / dense16 / dense32 — one native lane per coordinate
    packed4 / packed8 / packed16        — bit-packed int32 transport words
    logged:<name>                       — byte-metering wrapper around <name>
"""
from __future__ import annotations

from repro.wire.base import WireFormat, WireRangeError
from repro.wire.bucketing import (
    BucketManifest,
    bucketize,
    debucketize,
    plan_buckets,
)
from repro.wire.dense import DenseInt
from repro.wire.logged import Logged
from repro.wire.packed import PackedInt

__all__ = [
    "WireFormat",
    "WireRangeError",
    "DenseInt",
    "PackedInt",
    "Logged",
    "BucketManifest",
    "bucketize",
    "debucketize",
    "plan_buckets",
    "make_wire_format",
]


def make_wire_format(name):
    """Resolve a codec spec (name string or WireFormat instance)."""
    if not isinstance(name, str):
        return name  # already a codec
    if name.startswith("logged:"):
        return Logged(make_wire_format(name[len("logged:"):]))
    reg = {
        "dense4": lambda: DenseInt(bits=4),
        "dense8": lambda: DenseInt(bits=8),
        "dense16": lambda: DenseInt(bits=16),
        "dense32": lambda: DenseInt(bits=32),
        "packed4": lambda: PackedInt(bits=4),
        "packed8": lambda: PackedInt(bits=8),
        "packed16": lambda: PackedInt(bits=16),
    }
    if name not in reg:
        raise ValueError(f"unknown wire format {name!r}; options {sorted(reg)}")
    return reg[name]()
