"""Pluggable wire-codec subsystem: everything between rounded integers and
the transport collective. See :mod:`repro.wire.base` for the WireFormat
contract (psum- and gather-shaped payloads).

Registry names accepted everywhere a codec can be configured
(``make_compressor(..., wire=...)``, ``build_train_step(..., wire=...)``,
``repro.launch.train --wire``):

    dense4 / dense8 / dense16 / dense32 — one native lane per coordinate
    packed4 / packed8 / packed16        — bit-packed int32 transport words
    topk8:<k> / topk16:<k>              — top-k values + index plane (gather)
    logged:<name>                       — byte-metering wrapper around <name>

``WIRE_FORMATS``/``PARAMETRIC_WIRE_FORMATS`` are the single registry; the
CLI ``--wire`` help, the unknown-name error, and the analysis matrix sweep
all read :func:`wire_format_names` instead of hand-maintaining lists.
"""
from __future__ import annotations

from repro.wire.base import WireFormat, WireRangeError, payload_nbytes
from repro.wire.bucketing import (
    BucketManifest,
    bucketize,
    debucketize,
    debucketize_gathered,
    plan_buckets,
)
from repro.wire.dense import DenseInt
from repro.wire.logged import Logged
from repro.wire.packed import PackedInt
from repro.wire.topk import TopKInt

__all__ = [
    "WireFormat",
    "WireRangeError",
    "DenseInt",
    "PackedInt",
    "TopKInt",
    "Logged",
    "BucketManifest",
    "bucketize",
    "debucketize",
    "debucketize_gathered",
    "plan_buckets",
    "payload_nbytes",
    "make_wire_format",
    "wire_format_names",
    "WIRE_FORMATS",
    "PARAMETRIC_WIRE_FORMATS",
]

# The one registry. Fixed names map to zero-arg factories; parametric names
# take a ":<k>" suffix and map to int-arg factories.
WIRE_FORMATS = {
    "dense4": lambda: DenseInt(bits=4),
    "dense8": lambda: DenseInt(bits=8),
    "dense16": lambda: DenseInt(bits=16),
    "dense32": lambda: DenseInt(bits=32),
    "packed4": lambda: PackedInt(bits=4),
    "packed8": lambda: PackedInt(bits=8),
    "packed16": lambda: PackedInt(bits=16),
}

PARAMETRIC_WIRE_FORMATS = {
    "topk8": lambda k: TopKInt(bits=8, k=k),
    "topk16": lambda k: TopKInt(bits=16, k=k),
}


def wire_format_names():
    """Every accepted codec name, parametric ones shown with their suffix —
    the list the CLI help and the unknown-name error both print."""
    return sorted(WIRE_FORMATS) + sorted(
        f"{p}:<k>" for p in PARAMETRIC_WIRE_FORMATS
    )


def make_wire_format(name):
    """Resolve a codec spec (name string or WireFormat instance)."""
    if not isinstance(name, str):
        return name  # already a codec
    if name.startswith("logged:"):
        return Logged(make_wire_format(name[len("logged:"):]))
    if name in WIRE_FORMATS:
        return WIRE_FORMATS[name]()
    prefix, sep, arg = name.partition(":")
    if sep and prefix in PARAMETRIC_WIRE_FORMATS:
        try:
            k = int(arg)
        except ValueError:
            raise ValueError(
                f"unknown wire format {name!r}: {prefix}:<k> needs an "
                f"integer k, got {arg!r}"
            ) from None
        return PARAMETRIC_WIRE_FORMATS[prefix](k)
    raise ValueError(
        f"unknown wire format {name!r}; options {wire_format_names()}"
    )
