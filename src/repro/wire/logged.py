"""Logged — transparent wrapper metering exact collective bytes.

Wraps any WireFormat and counts, at trace time, the exact payload bytes
every pack/unpack call would put on (take off) the collective, plus call
counts per leaf shape. Bytes are tree-summed over the payload's planes, so
the meter is transport-shape agnostic: a psum codec's single word plane and
a gather codec's vals+idx planes count the same way — and on the gather
route ``unpack_bytes`` naturally meters the n_workers× amplification of the
gathered planes, not just the one-worker psum payload. Because compressors
treat the codec as static Python state closed over by the step, one traced
step records one step's exact wire traffic — which is precisely what the
comm-volume benchmarks need, with no device work added (values pass through
untouched).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Tuple

import jax

from repro.wire.base import WireFormat, payload_nbytes


class Logged:
    """Byte-metering decorator over a WireFormat (same duck type)."""

    name = "logged"

    def __init__(self, inner: WireFormat):
        self.inner = inner
        self.reset()

    # ---- meter ----------------------------------------------------------
    def reset(self):
        self.pack_bytes = 0
        self.unpack_bytes = 0
        self.calls = defaultdict(int)  # (stage, shape) -> count

    def report(self) -> dict:
        return {
            "codec": f"logged({self.inner.name}{self.inner.bits})",
            "pack_bytes": self.pack_bytes,
            "unpack_bytes": self.unpack_bytes,
            "calls": dict(self.calls),
        }

    # ---- delegation -----------------------------------------------------
    @property
    def bits(self) -> int:
        return self.inner.bits

    @property
    def transport(self) -> str:
        return getattr(self.inner, "transport", "psum")

    @property
    def plane_names(self):
        return getattr(self.inner, "plane_names", ("words",))

    @property
    def fused_capable(self) -> bool:
        return getattr(self.inner, "fused_capable", True)

    def clip_limit(self, n_workers: int) -> int:
        return self.inner.clip_limit(n_workers)

    def encode(self, x, alpha, key, *, n_workers, stochastic=True):
        return self.inner.encode(
            x, alpha, key, n_workers=n_workers, stochastic=stochastic
        )

    def decode(self, ints, alpha, *, n_workers):
        return self.inner.decode(ints, alpha, n_workers=n_workers)

    def pack(self, ints: jax.Array, *, n_workers: int):
        words = self.inner.pack(ints, n_workers=n_workers)
        self.pack_bytes += payload_nbytes(words)
        self.calls[("pack", tuple(ints.shape))] += 1
        return words

    def unpack(self, words, shape: Tuple[int, ...], *, n_summed: int):
        self.unpack_bytes += payload_nbytes(words)
        self.calls[("unpack", tuple(shape))] += 1
        return self.inner.unpack(words, shape, n_summed=n_summed)

    def local_image(self, ints, *, n_workers):
        return self.inner.local_image(ints, n_workers=n_workers)

    def wire_bytes(self, size: int) -> int:
        return self.inner.wire_bytes(size)

    def fused_update(self, words, param, opt, scalars, *, kernel: str,
                     n_summed: int, shift=None):
        return self.inner.fused_update(
            words, param, opt, scalars,
            kernel=kernel, n_summed=n_summed, shift=shift,
        )
