"""DenseInt — one native integer lane per coordinate (the PR-1 transport).

pack is a cast to the narrowest native lane holding one `bits`-wide value
(int8 for bits<=8, int16, int32); the §5.1 clip makes the all-reduce
overflow-safe in that lane dtype, so unpack is just the widening cast back.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax
import jax.numpy as jnp

from repro.wire.base import WireFormat

# narrowest native lane holding one `bits`-wide value (mirrors
# repro.core.rounding.wire_dtype; kept local so repro.wire imports
# standalone — core/compressor.py imports this package)
_LANE = {4: jnp.int8, 8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


@dataclasses.dataclass(frozen=True)
class DenseInt(WireFormat):
    name: ClassVar[str] = "dense"

    @property
    def lane_dtype(self):
        return _LANE[self.bits]

    def pack(self, ints: jax.Array, *, n_workers: int) -> jax.Array:
        # the clip in encode() already guarantees the n-worker sum fits the
        # lane, so the narrowing cast is exact.
        return ints.astype(self.lane_dtype)

    def unpack(
        self, words: jax.Array, shape: Tuple[int, ...], *, n_summed: int
    ) -> jax.Array:
        return words.astype(jnp.int32)

    def wire_bytes(self, size: int) -> int:
        return int(size) * jnp.dtype(self.lane_dtype).itemsize

    def fused_update(self, words, param, opt, scalars, *, kernel: str,
                     n_summed: int, shift=None):
        from repro.kernels import ops as kops

        return kops.fused_apply(
            words, param, tuple(opt), scalars, shift, kernel=kernel
        )
