"""Bucketing — fixed-size transport-word buckets for the overlapped wire.

The overlap subsystem's unit of communication is a BUCKET: a fixed-size
contiguous run of transport words cut from the concatenation of every leaf's
packed payload. Buckets exist so the integer all-reduce can be issued as
several independent collectives instead of one monolithic psum — XLA's
latency-hiding scheduler is then free to interleave bucket k's ring transfer
with whatever compute (the next microbatch's backward, the unpack of bucket
k-1) is still pending.

The mapping is purely structural and exactly invertible::

    bucketize            : payload tree -> [bucket_0, ..., bucket_{B-1}]
                           (1-D, fixed ``bucket_words`` each, ragged tail)
    debucketize          : buckets      -> payload tree          (bit-exact)
    debucketize_gathered : gathered (n, s) buckets -> payload tree with a
                           leading worker axis per plane         (bit-exact)

with the :class:`BucketManifest` (all-static: treedef, per-plane shapes,
offsets, bucket sizes) recording how to invert. A payload tree's leaves are
its transport PLANES — one word plane per parameter leaf for psum codecs, or
several named planes (vals + idx) per leaf for gather codecs; the manifest's
``leaf_planes`` records which plane each flattened leaf is, so the
multi-plane payload inverts exactly through the same slicing. No value ever
changes — the manifest is bookkeeping, so the bucketed route transports
exactly the same words as the serial route (zero byte inflation; the parity
guarantee of the overlap contract reduces to the exactness of integer
addition on the psum route and of concatenation/slicing on the gather one).

Every plane of one codec shares a single transport dtype (int32 words for
PackedInt and both TopKInt planes, one narrow lane dtype for DenseInt),
which is what makes the cross-leaf concatenation legal; a mixed-dtype tree
is a configuration error and raises.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "BucketManifest",
    "plan_buckets",
    "bucketize",
    "debucketize",
    "debucketize_gathered",
]

DEFAULT_BUCKET_WORDS = 1 << 16  # 256 KiB of int32 words per bucket


@dataclasses.dataclass(frozen=True)
class BucketManifest:
    """Static inversion record for one (words tree, bucket_words) pairing.

    ``leaf_shapes``/``leaf_sizes``/``leaf_planes`` follow ``treedef``'s
    flatten order — ``leaf_planes[i]`` names the transport plane leaf ``i``
    is ("words" for a psum codec's single plane; "vals"/"idx"/... keyed off
    the payload dict for gather codecs), and ``leaf_offsets[i]`` is its word
    offset into the concatenated flat payload. ``bucket_sizes`` lists each
    bucket's word count (all ``bucket_words`` except possibly the ragged
    last). ``total_words`` is their sum — exactly the serial route's word
    count, pinned by :mod:`benchmarks.bench_overlap`.
    """

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_sizes: Tuple[int, ...]
    dtype: Any
    bucket_words: int
    bucket_sizes: Tuple[int, ...]
    leaf_planes: Tuple[str, ...] = ()

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def leaf_offsets(self) -> Tuple[int, ...]:
        """Word offset of each plane in the concatenated flat payload."""
        offs, off = [], 0
        for size in self.leaf_sizes:
            offs.append(off)
            off += size
        return tuple(offs)

    @property
    def total_words(self) -> int:
        return sum(self.bucket_sizes)

    @property
    def payload_bytes(self) -> int:
        """Exact bytes one worker's bucketed payload puts on the collective —
        identical to the serial route's (bucketing adds no padding)."""
        return self.total_words * jnp.dtype(self.dtype).itemsize

    def ring_collectives(self, dp_sizes) -> Tuple[int, int]:
        """``(n_eqns, operand_bytes)`` the bucketed ring route emits for ONE
        image of this manifest over the given dp axis sizes: per bucket of
        ``s`` words and per axis of size n > 1, ``ring_allreduce_int`` issues
        (n-1) ppermute hops + 1 all_gather, each moving an ⌈s/n⌉-word chunk
        (a size-1 axis short-circuits in Python and emits nothing).

        This is the runtime side of the static transport model — the
        analyzer's :func:`repro.analysis.traffic.plan_transport` computes the
        same numbers from the :class:`~repro.analysis.wire_audit.WireSpec`
        alone, and tests/test_schedule.py pins the two equal so
        benchmarks/bench_overlap.py can cross-check its runtime collective
        counts against the manifest without tracing anything."""
        itemsize = jnp.dtype(self.dtype).itemsize
        n_eqns = 0
        words = 0
        for s in self.bucket_sizes:
            for n in dp_sizes:
                if n <= 1:
                    continue
                n_eqns += n
                words += n * (-(-s // n))
        return n_eqns, words * itemsize

    def gather_collectives(self, dp_sizes) -> Tuple[int, int]:
        """``(n_eqns, operand_bytes)`` the gather transport emits for ONE
        image of this manifest: per bucket of ``s`` words,
        ``allgather_wire_words`` issues one ``all_gather`` per dp axis of
        size > 1 in REVERSED axis order, each eqn's operand being the bucket
        already grown by every previously gathered axis (a size-1 axis
        short-circuits in Python and emits nothing).

        Runtime counterpart of the static gather branch of
        :func:`repro.analysis.traffic.plan_transport`; tests pin the two
        equal, mirroring :meth:`ring_collectives` for the psum route."""
        itemsize = jnp.dtype(self.dtype).itemsize
        sizes = [n for n in dp_sizes if n > 1]
        n_eqns = 0
        words = 0
        for s in self.bucket_sizes:
            grown = s
            for n in reversed(sizes):
                n_eqns += 1
                words += grown
                grown *= n
        return n_eqns, words * itemsize


def _plane_label(path) -> str:
    """Plane name of one flattened payload leaf: the innermost dict key of
    its tree path (gather codecs pack {"vals": ..., "idx": ...} per leaf),
    else the psum codec's single implicit "words" plane."""
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return "words"


def plan_buckets(words_tree, *, bucket_words: int = DEFAULT_BUCKET_WORDS) -> BucketManifest:
    """Derive the manifest from a (concrete or abstract) transport payload
    tree — the leaves are the codec's planes, labelled via their tree path."""
    if bucket_words <= 0:
        raise ValueError(f"bucket_words must be positive, got {bucket_words}")
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(words_tree)
    leaves = [l for _, l in paths_leaves]
    planes = tuple(_plane_label(p) for p, _ in paths_leaves)
    if not leaves:
        raise ValueError("cannot bucket an empty transport tree")
    dtypes = {jnp.dtype(l.dtype) for l in leaves}
    if len(dtypes) != 1:
        raise ValueError(
            f"bucketing needs one transport dtype across all leaves, got "
            f"{sorted(str(d) for d in dtypes)} — one wire codec per tree"
        )
    sizes = tuple(int(math.prod(l.shape)) for l in leaves)
    total = sum(sizes)
    full, tail = divmod(total, bucket_words)
    bucket_sizes = (bucket_words,) * full + ((tail,) if tail else ())
    return BucketManifest(
        treedef=treedef,
        leaf_shapes=tuple(tuple(l.shape) for l in leaves),
        leaf_sizes=sizes,
        dtype=dtypes.pop(),
        bucket_words=bucket_words,
        bucket_sizes=bucket_sizes,
        leaf_planes=planes,
    )


def bucketize(words_tree, manifest: BucketManifest) -> List[jax.Array]:
    """words tree -> list of 1-D buckets (fixed size, ragged tail)."""
    leaves = jax.tree.leaves(words_tree)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    out, off = [], 0
    for size in manifest.bucket_sizes:
        out.append(flat[off : off + size])
        off += size
    return out


def debucketize(buckets: List[jax.Array], manifest: BucketManifest):
    """Exact inverse of :func:`bucketize` (same words, same tree)."""
    if len(buckets) != manifest.n_buckets:
        raise ValueError(
            f"manifest expects {manifest.n_buckets} buckets, got {len(buckets)}"
        )
    flat = jnp.concatenate([b.reshape(-1) for b in buckets])
    leaves, off = [], 0
    for shape, size in zip(manifest.leaf_shapes, manifest.leaf_sizes):
        leaves.append(flat[off : off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(manifest.treedef, leaves)


def debucketize_gathered(buckets: List[jax.Array], manifest: BucketManifest):
    """Invert :func:`bucketize` on GATHERED buckets — each arrives as
    ``(n_workers, bucket_size)`` — yielding the payload tree with a leading
    worker axis on every plane (what a gather codec's unpack consumes).

    Per worker row this is exactly :func:`debucketize`; no value changes.
    """
    if len(buckets) != manifest.n_buckets:
        raise ValueError(
            f"manifest expects {manifest.n_buckets} buckets, got {len(buckets)}"
        )
    n = int(buckets[0].shape[0])
    flat = jnp.concatenate([b.reshape(n, -1) for b in buckets], axis=1)
    leaves, off = [], 0
    for shape, size in zip(manifest.leaf_shapes, manifest.leaf_sizes):
        leaves.append(flat[:, off : off + size].reshape((n,) + tuple(shape)))
        off += size
    return jax.tree.unflatten(manifest.treedef, leaves)
