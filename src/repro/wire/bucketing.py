"""Bucketing — fixed-size transport-word buckets for the overlapped wire.

The overlap subsystem's unit of communication is a BUCKET: a fixed-size
contiguous run of transport words cut from the concatenation of every leaf's
packed payload. Buckets exist so the integer all-reduce can be issued as
several independent collectives instead of one monolithic psum — XLA's
latency-hiding scheduler is then free to interleave bucket k's ring transfer
with whatever compute (the next microbatch's backward, the unpack of bucket
k-1) is still pending.

The mapping is purely structural and exactly invertible::

    bucketize   : words tree -> [bucket_0, ..., bucket_{B-1}]   (1-D, fixed
                  ``bucket_words`` each except a ragged tail)
    debucketize : buckets    -> words tree                      (bit-exact)

with the :class:`BucketManifest` (all-static: treedef, per-leaf shapes,
offsets, bucket sizes) recording how to invert. No value ever changes — the
manifest is slicing bookkeeping, so the bucketed route transports exactly the
same words as the serial route (zero byte inflation; the parity guarantee of
the overlap contract reduces to the exactness of integer addition).

Every leaf of one codec shares a single transport dtype (int32 words for
PackedInt, one narrow lane dtype for DenseInt), which is what makes the
cross-leaf concatenation legal; a mixed-dtype tree is a configuration error
and raises.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

__all__ = ["BucketManifest", "plan_buckets", "bucketize", "debucketize"]

DEFAULT_BUCKET_WORDS = 1 << 16  # 256 KiB of int32 words per bucket


@dataclasses.dataclass(frozen=True)
class BucketManifest:
    """Static inversion record for one (words tree, bucket_words) pairing.

    ``leaf_shapes``/``leaf_sizes`` follow ``treedef``'s flatten order;
    ``bucket_sizes`` lists each bucket's word count (all ``bucket_words``
    except possibly the ragged last). ``total_words`` is their sum — exactly
    the serial route's word count, pinned by :mod:`benchmarks.bench_overlap`.
    """

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_sizes: Tuple[int, ...]
    dtype: Any
    bucket_words: int
    bucket_sizes: Tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def total_words(self) -> int:
        return sum(self.bucket_sizes)

    @property
    def payload_bytes(self) -> int:
        """Exact bytes one worker's bucketed payload puts on the collective —
        identical to the serial route's (bucketing adds no padding)."""
        return self.total_words * jnp.dtype(self.dtype).itemsize

    def ring_collectives(self, dp_sizes) -> Tuple[int, int]:
        """``(n_eqns, operand_bytes)`` the bucketed ring route emits for ONE
        image of this manifest over the given dp axis sizes: per bucket of
        ``s`` words and per axis of size n > 1, ``ring_allreduce_int`` issues
        (n-1) ppermute hops + 1 all_gather, each moving an ⌈s/n⌉-word chunk
        (a size-1 axis short-circuits in Python and emits nothing).

        This is the runtime side of the static transport model — the
        analyzer's :func:`repro.analysis.traffic.plan_transport` computes the
        same numbers from the :class:`~repro.analysis.wire_audit.WireSpec`
        alone, and tests/test_schedule.py pins the two equal so
        benchmarks/bench_overlap.py can cross-check its runtime collective
        counts against the manifest without tracing anything."""
        itemsize = jnp.dtype(self.dtype).itemsize
        n_eqns = 0
        words = 0
        for s in self.bucket_sizes:
            for n in dp_sizes:
                if n <= 1:
                    continue
                n_eqns += n
                words += n * (-(-s // n))
        return n_eqns, words * itemsize


def plan_buckets(words_tree, *, bucket_words: int = DEFAULT_BUCKET_WORDS) -> BucketManifest:
    """Derive the manifest from a (concrete or abstract) transport-word tree."""
    if bucket_words <= 0:
        raise ValueError(f"bucket_words must be positive, got {bucket_words}")
    leaves, treedef = jax.tree.flatten(words_tree)
    if not leaves:
        raise ValueError("cannot bucket an empty transport tree")
    dtypes = {jnp.dtype(l.dtype) for l in leaves}
    if len(dtypes) != 1:
        raise ValueError(
            f"bucketing needs one transport dtype across all leaves, got "
            f"{sorted(str(d) for d in dtypes)} — one wire codec per tree"
        )
    sizes = tuple(int(math.prod(l.shape)) for l in leaves)
    total = sum(sizes)
    full, tail = divmod(total, bucket_words)
    bucket_sizes = (bucket_words,) * full + ((tail,) if tail else ())
    return BucketManifest(
        treedef=treedef,
        leaf_shapes=tuple(tuple(l.shape) for l in leaves),
        leaf_sizes=sizes,
        dtype=dtypes.pop(),
        bucket_words=bucket_words,
        bucket_sizes=bucket_sizes,
    )


def bucketize(words_tree, manifest: BucketManifest) -> List[jax.Array]:
    """words tree -> list of 1-D buckets (fixed size, ragged tail)."""
    leaves = jax.tree.leaves(words_tree)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    out, off = [], 0
    for size in manifest.bucket_sizes:
        out.append(flat[off : off + size])
        off += size
    return out


def debucketize(buckets: List[jax.Array], manifest: BucketManifest):
    """Exact inverse of :func:`bucketize` (same words, same tree)."""
    if len(buckets) != manifest.n_buckets:
        raise ValueError(
            f"manifest expects {manifest.n_buckets} buckets, got {len(buckets)}"
        )
    flat = jnp.concatenate([b.reshape(-1) for b in buckets])
    leaves, off = [], 0
    for shape, size in zip(manifest.leaf_shapes, manifest.leaf_sizes):
        leaves.append(flat[off : off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(manifest.treedef, leaves)
