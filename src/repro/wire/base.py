"""WireFormat — the pluggable codec between "rounded integers" and the wire.

The paper's headline property is a wire that carries *no floats*. Everything
that happens between a worker's float gradient and the aggregated integer
image is the wire codec's business, split into four orthogonal stages::

    encode : f32 tensor, α, key  ->  clipped integer image (canonical int32)
    pack   : integer image       ->  transport PAYLOAD (≥1 integer planes)
    unpack : transported payload ->  summed integer image (int32)
    decode : summed image, α     ->  gradient estimate (1/(nα)) Σ Int(α g_i)

A payload is a pytree of integer PLANES. The two transport shapes:

* ``transport = "psum"`` (DenseInt, PackedInt): pack returns a single
  summable plane — a bare array of transport words — and the wire is an
  integer all-reduce of that plane. Psum-safety contract::

      unpack(Σ_i pack(ints_i), n) == Σ_i ints_i     elementwise, exactly,

  for any n tensors whose entries respect the §5.1 clip
  |v| <= clip_limit(n). The Σ on the left is the wire all-reduce in the
  transport-word dtype (wrap-around integer addition); the Σ on the right is
  the mathematical sum.

* ``transport = "gather"`` (TopKInt): pack returns a dict of named planes
  (``plane_names``) whose coordinates are only meaningful together — e.g. a
  value plane plus the index plane that positions it — so no sum may cross
  the wire. The transport is an integer all-gather of the payload and unpack
  receives every plane with a leading worker axis of length ``n_summed``.
  Gather-safety contract::

      unpack(stack_i(pack(ints_i)), n) == Σ_i local_image(ints_i)

  where :meth:`local_image` is the lossy image one worker's payload decodes
  to (identity for psum codecs; the top-k-masked image for sparse ones).

Either way the compressor reasons about exact integer sums while the
transport representation stays swappable (dense lanes, bit-packed words,
sparse value+index planes, future entropy-coded wires).

Call sites select a codec through the compressor's ``wire`` field (or the
``wire=`` argument of ``launch.step.build_train_step``); new transports
extend :mod:`repro.wire`, not the call sites.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax
import jax.numpy as jnp

# NOTE: no module-level repro.core imports here (or anywhere in repro.wire):
# core/compressor.py imports this package, so the wire package must be
# importable standalone; the Int-operator primitives are pulled lazily.

__all__ = ["WireFormat", "WireRangeError", "clip_limit", "payload_nbytes"]

_INT_RANGE = {4: 7, 8: 127, 16: 32767, 32: 2147483647}


class WireRangeError(ValueError):
    """The wire configuration cannot represent the n-worker sum.

    Raised when the §5.1 clip limit ``(2^(b-1)-1) // n_workers`` degenerates
    to 0 — every local integer would be clipped to 0 and the whole gradient
    silently zeroed (e.g. 256 workers on an int8 wire). The fix is a wider
    wire (`bits`) or fewer workers per integer all-reduce group.
    """


def clip_limit(*, n_workers: int, bits: int) -> int:
    """The §5.1 clip limit: largest |v| such that the n-worker sum fits
    `bits`. Raises :class:`WireRangeError` on the degenerate range."""
    if bits not in _INT_RANGE:
        raise ValueError(f"unsupported wire width {bits}")
    lim = _INT_RANGE[bits] // max(n_workers, 1)
    if lim == 0:
        raise WireRangeError(
            f"int{bits} wire cannot carry a sum over {n_workers} workers: "
            f"clip limit (2^{bits - 1}-1)//{n_workers} == 0 would zero every "
            f"gradient. Use a wider wire (bits>={bits * 2}) or fewer workers "
            f"per integer all-reduce group."
        )
    return lim


def payload_nbytes(payload) -> int:
    """Exact bytes of one payload (tree-sum over its integer planes).

    Works on concrete arrays and abstract ShapeDtypeStructs alike — this is
    the single definition :class:`repro.wire.logged.Logged` meters with, so
    psum payloads (one plane) and gather payloads (several) are counted the
    same way.
    """
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(payload))


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Base codec: shared encode/decode; transport stages are per-format.

    ``bits`` is the VALUE width of one transported coordinate. How those
    values ride the physical lanes is what subclasses define via
    pack/unpack: the *payload* a subclass packs is a pytree of integer
    planes — a single summable word plane for psum-transport codecs (one
    narrow lane per coordinate, or several coordinates packed into an int32
    word), or several named planes (``plane_names``, e.g. values + indices)
    for gather-transport codecs where no cross-worker sum is legal on the
    wire. ``transport`` declares which collective shape the payload rides;
    ``fused_capable`` declares whether the codec has a fused decode+update
    kernel (the codec half of the capability dispatch in
    ``launch.step._fused_plan``).
    """

    name: ClassVar[str] = "base"
    transport: ClassVar[str] = "psum"  # "psum" | "gather"
    plane_names: ClassVar[Tuple[str, ...]] = ("words",)
    fused_capable: ClassVar[bool] = True

    bits: int = 32
    use_kernels: bool = False  # route hot stages through the Pallas kernels

    # ---- shared value stages -------------------------------------------
    def clip_limit(self, n_workers: int) -> int:
        """§5.1 limit; raises WireRangeError when it degenerates to 0."""
        return clip_limit(n_workers=n_workers, bits=self.bits)

    def encode(
        self,
        x: jax.Array,
        alpha: jax.Array,
        key: jax.Array | None,
        *,
        n_workers: int,
        stochastic: bool = True,
    ) -> jax.Array:
        """x -> Int(α ∘ x) clipped for the n-worker sum, canonical int32."""
        lim = self.clip_limit(n_workers)
        if self.use_kernels:
            from repro.kernels import ops as kops

            return kops.int_compress(
                x, alpha, key, n_workers=n_workers, bits=self.bits,
                stochastic=stochastic,
            )
        from repro.core import rounding  # lazy: core imports this package

        r = rounding.int_round(
            x.astype(jnp.float32) * alpha, key, stochastic=stochastic
        )
        return jnp.clip(r, -lim, lim).astype(jnp.int32)

    def decode(
        self, ints: jax.Array, alpha: jax.Array, *, n_workers: int
    ) -> jax.Array:
        """Summed integer image -> gradient estimate (1/(nα)) Σ Int(α g_i)."""
        return ints.astype(jnp.float32) / (n_workers * alpha)

    # ---- transport stages (per-format) ---------------------------------
    def pack(self, ints: jax.Array, *, n_workers: int):
        """Integer image -> transport payload.

        Psum codecs return a single summable plane (a bare array of words);
        gather codecs return a dict of ``plane_names`` planes. All planes of
        one codec share a single integer dtype so the bucketed route can
        concatenate them.
        """
        raise NotImplementedError

    def unpack(self, payload, shape: Tuple[int, ...], *, n_summed: int) -> jax.Array:
        """Transported payload -> summed integer image (int32).

        For psum codecs ``payload`` is the all-reduced word plane and
        ``n_summed`` the number of contributions folded into it (needed to
        strip n× biases). For gather codecs every plane arrives with a
        leading worker axis of length ``n_summed`` and unpack performs the
        sum itself (scatter-add of each worker's contribution).
        """
        raise NotImplementedError

    def local_image(self, ints: jax.Array, *, n_workers: int) -> jax.Array:
        """The integer image the decoder attributes to THIS worker.

        Identity for lossless-transport (psum) codecs. Sparse codecs
        override it with the same selection pack performs (top-k mask), so
        error-feedback compressors can compute the transmitted-vs-encoded
        residual without unpacking their own payload.
        """
        return ints

    def wire_bytes(self, size: int) -> int:
        """Exact bytes one worker's `size`-coordinate payload puts on the
        collective, summed over all planes (the quantity bench_comm_volume
        meters)."""
        raise NotImplementedError

    def fused_update(
        self,
        words: jax.Array,
        param: jax.Array,
        opt: Tuple[jax.Array, ...],
        scalars: jax.Array,
        *,
        kernel: str,
        n_summed: int,
        shift: jax.Array | None = None,
    ):
        """Fused decode + optimizer step straight off the transport words
        (the Pallas route) — the codec half of the capability-dispatch
        contract. ``kernel`` names the optimizer arithmetic
        (``Optimizer.fused_kernel``: "sgd" | "adamw"), ``opt`` carries that
        kernel's per-leaf f32 state tensors in
        ``optim.base.FUSED_STATE_TENSORS`` order, and ``scalars`` the
        canonical f32 vector documented in :mod:`repro.kernels.fused_update`
        (``[inv_nalpha, clip, *FUSED_SCALAR_TAIL[kernel]]``). ``shift`` is
        the optional replicated global shift h (IntDIANA): the kernel
        decodes g = h + Σints·inv_nalpha and emits the new shift (= g)
        alongside.

        Returns ``(new_param, new_opt, new_shift | None)`` without
        materializing the unpacked integer image in HBM."""
        raise NotImplementedError
