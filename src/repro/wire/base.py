"""WireFormat — the pluggable codec between "rounded integers" and psum.

The paper's headline property is a wire that carries *no floats*. Everything
that happens between a worker's float gradient and the all-reduced integer
image is the wire codec's business, split into four orthogonal stages::

    encode : f32 tensor, α, key  ->  clipped integer image (canonical int32)
    pack   : integer image       ->  transport words (what the psum carries)
    unpack : summed words        ->  summed integer image (int32)
    decode : summed image, α     ->  gradient estimate (1/(nα)) Σ Int(α g_i)

Psum-safety contract (every implementation MUST satisfy it)::

    unpack(Σ_i pack(ints_i), n) == Σ_i ints_i     elementwise, exactly,

for any n tensors whose entries respect the §5.1 clip |v| <= clip_limit(n).
The Σ on the left is the wire all-reduce in the transport-word dtype
(wrap-around integer addition); the Σ on the right is the mathematical sum.
This is what lets compressors reason about integer sums while the transport
representation stays swappable (dense lanes today, bit-packed words, future
entropy-coded or double-buffered wires).

Call sites select a codec through the compressor's ``wire`` field (or the
``wire=`` argument of ``launch.step.build_train_step``); new transports
extend :mod:`repro.wire`, not the call sites.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax
import jax.numpy as jnp

# NOTE: no module-level repro.core imports here (or anywhere in repro.wire):
# core/compressor.py imports this package, so the wire package must be
# importable standalone; the Int-operator primitives are pulled lazily.

__all__ = ["WireFormat", "WireRangeError", "clip_limit"]

_INT_RANGE = {4: 7, 8: 127, 16: 32767, 32: 2147483647}


class WireRangeError(ValueError):
    """The wire configuration cannot represent the n-worker sum.

    Raised when the §5.1 clip limit ``(2^(b-1)-1) // n_workers`` degenerates
    to 0 — every local integer would be clipped to 0 and the whole gradient
    silently zeroed (e.g. 256 workers on an int8 wire). The fix is a wider
    wire (`bits`) or fewer workers per integer all-reduce group.
    """


def clip_limit(*, n_workers: int, bits: int) -> int:
    """The §5.1 clip limit: largest |v| such that the n-worker sum fits
    `bits`. Raises :class:`WireRangeError` on the degenerate range."""
    if bits not in _INT_RANGE:
        raise ValueError(f"unsupported wire width {bits}")
    lim = _INT_RANGE[bits] // max(n_workers, 1)
    if lim == 0:
        raise WireRangeError(
            f"int{bits} wire cannot carry a sum over {n_workers} workers: "
            f"clip limit (2^{bits - 1}-1)//{n_workers} == 0 would zero every "
            f"gradient. Use a wider wire (bits>={bits * 2}) or fewer workers "
            f"per integer all-reduce group."
        )
    return lim


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Base codec: shared encode/decode; transport stages are per-format.

    ``bits`` is the VALUE width: the §5.1 clip guarantees the n-worker sum of
    any coordinate fits a signed `bits`-wide field. How those fields ride the
    physical lanes (one narrow lane each, or several packed into an int32
    word) is what subclasses define via pack/unpack.
    """

    name: ClassVar[str] = "base"

    bits: int = 32
    use_kernels: bool = False  # route hot stages through the Pallas kernels

    # ---- shared value stages -------------------------------------------
    def clip_limit(self, n_workers: int) -> int:
        """§5.1 limit; raises WireRangeError when it degenerates to 0."""
        return clip_limit(n_workers=n_workers, bits=self.bits)

    def encode(
        self,
        x: jax.Array,
        alpha: jax.Array,
        key: jax.Array | None,
        *,
        n_workers: int,
        stochastic: bool = True,
    ) -> jax.Array:
        """x -> Int(α ∘ x) clipped for the n-worker sum, canonical int32."""
        lim = self.clip_limit(n_workers)
        if self.use_kernels:
            from repro.kernels import ops as kops

            return kops.int_compress(
                x, alpha, key, n_workers=n_workers, bits=self.bits,
                stochastic=stochastic,
            )
        from repro.core import rounding  # lazy: core imports this package

        r = rounding.int_round(
            x.astype(jnp.float32) * alpha, key, stochastic=stochastic
        )
        return jnp.clip(r, -lim, lim).astype(jnp.int32)

    def decode(
        self, ints: jax.Array, alpha: jax.Array, *, n_workers: int
    ) -> jax.Array:
        """Summed integer image -> gradient estimate (1/(nα)) Σ Int(α g_i)."""
        return ints.astype(jnp.float32) / (n_workers * alpha)

    # ---- transport stages (per-format) ---------------------------------
    def pack(self, ints: jax.Array, *, n_workers: int) -> jax.Array:
        raise NotImplementedError

    def unpack(
        self, words: jax.Array, shape: Tuple[int, ...], *, n_summed: int
    ) -> jax.Array:
        raise NotImplementedError

    def wire_bytes(self, size: int) -> int:
        """Exact bytes one worker's `size`-coordinate payload puts on the
        collective (the quantity bench_comm_volume meters)."""
        raise NotImplementedError

    def fused_update(
        self,
        words: jax.Array,
        param: jax.Array,
        opt: Tuple[jax.Array, ...],
        scalars: jax.Array,
        *,
        kernel: str,
        n_summed: int,
        shift: jax.Array | None = None,
    ):
        """Fused decode + optimizer step straight off the transport words
        (the Pallas route) — the codec half of the capability-dispatch
        contract. ``kernel`` names the optimizer arithmetic
        (``Optimizer.fused_kernel``: "sgd" | "adamw"), ``opt`` carries that
        kernel's per-leaf f32 state tensors in
        ``optim.base.FUSED_STATE_TENSORS`` order, and ``scalars`` the
        canonical f32 vector documented in :mod:`repro.kernels.fused_update`
        (``[inv_nalpha, clip, *FUSED_SCALAR_TAIL[kernel]]``). ``shift`` is
        the optional replicated global shift h (IntDIANA): the kernel
        decodes g = h + Σints·inv_nalpha and emits the new shift (= g)
        alongside.

        Returns ``(new_param, new_opt, new_shift | None)`` without
        materializing the unpacked integer image in HBM."""
        raise NotImplementedError
