"""PackedInt — k sub-words bit-packed into each int32 lane (SwitchML-style).

Layout (the canonical wire layout, shared bit-for-bit by the Pallas kernels
in :mod:`repro.kernels`): the flat integer image of size d is zero-padded to
k·m with m = ceil(d/k) words, split into k contiguous chunks, and chunk j is
stored in bit-field j of every word::

    word[w] = Σ_j (flat[j·m + w] + lim) << (j·bits)        (mod 2^32)

Guard-bit / bias invariant: each field carries v + lim >= 0 with
lim = clip_limit(n) = (2^(bits-1)-1)//n, so the n-worker field sum is
Σ v_i + n·lim ∈ [0, 2n·lim] ⊆ [0, 2^bits - 2] — it NEVER carries into the
neighbouring field. Word addition wraps mod 2^32 (psum of int32), which is
exact for the per-field arithmetic; unpack shifts+masks each field out and
subtracts the accumulated bias n·lim. That is the psum-safety contract of
:class:`repro.wire.base.WireFormat`, proven by tests/test_wire.py.

Wire cost: 4·ceil(d/k) bytes per worker — bits/8 bytes per coordinate, i.e.
4× fewer than the int32 transport for the int8 recipe and 8× fewer for int4
(a width the dense transport cannot ride at all: its narrowest lane is int8).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import jax
import jax.numpy as jnp

from repro.wire.base import WireFormat

_ALLOWED_BITS = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class PackedInt(WireFormat):
    name: ClassVar[str] = "packed"

    bits: int = 8

    def __post_init__(self):
        if self.bits not in _ALLOWED_BITS:
            raise ValueError(
                f"PackedInt packs sub-int32 fields; bits must be one of "
                f"{_ALLOWED_BITS}, got {self.bits} (use DenseInt for int32)"
            )

    @property
    def fields(self) -> int:
        """Sub-words per int32 transport word."""
        return 32 // self.bits

    def words_len(self, size: int) -> int:
        return -(-int(size) // self.fields)

    def pack(self, ints: jax.Array, *, n_workers: int) -> jax.Array:
        lim = self.clip_limit(n_workers)
        if self.use_kernels:
            from repro.kernels import ops as kops

            return kops.pack_words(
                ints, bits=self.bits, n_workers=n_workers
            )
        k, b = self.fields, self.bits
        flat = ints.reshape(-1).astype(jnp.int32)
        m = self.words_len(flat.size)
        chunks = jnp.pad(flat, (0, k * m - flat.size)).reshape(k, m)
        word = jnp.zeros((m,), jnp.int32)
        for j in range(k):  # k is static; the adds fuse into one pass
            word = word + ((chunks[j] + lim) << (j * b))
        return word

    def unpack(
        self, words: jax.Array, shape: Tuple[int, ...], *, n_summed: int
    ) -> jax.Array:
        lim = self.clip_limit(n_summed)
        size = 1
        for s in shape:
            size *= int(s)
        if self.use_kernels:
            from repro.kernels import ops as kops

            return kops.unpack_words(
                words, shape, bits=self.bits, n_summed=n_summed
            )
        k, b = self.fields, self.bits
        mask = (1 << b) - 1
        # arithmetic >> then mask keeps exactly original bits [j·b, (j+1)·b):
        # sign-extension only touches positions the mask drops.
        fields = [
            ((words >> (j * b)) & mask) - n_summed * lim for j in range(k)
        ]
        flat = jnp.stack(fields).reshape(-1)[:size]
        return flat.astype(jnp.int32).reshape(shape)

    def wire_bytes(self, size: int) -> int:
        return 4 * self.words_len(size)

    def fused_update(self, words, param, opt, scalars, *, kernel: str,
                     n_summed: int, shift=None):
        from repro.kernels import ops as kops

        return kops.fused_unpack_apply(
            words, param, tuple(opt), scalars, shift,
            kernel=kernel, bits=self.bits, n_summed=n_summed,
        )
