"""Batched serving engine: continuous-batching style decode loop.

Single-device reference implementation of the serve path (the full-scale
sharded decode is what the dry-run lowers via launch/step.py). Features:
  * slot-based continuous batching: requests claim free slots, finished
    sequences free them without stalling the batch;
  * prompt prefill via the decode path (recurrent families) — O(1) state;
  * greedy sampling through the TP-aware tp_greedy (degenerates to argmax
    on one device).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import Axes
from repro.models.decode import init_lm_cache, lm_decode_step, tp_greedy
from repro.parallel import collectives as coll


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 256,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.axes = Axes()
        self.slots = slots
        self.max_seq = max_seq
        self.cache = init_lm_cache(cfg, 1, 1, slots, max_seq)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.pending: List[Request] = []
        if mesh is not None:
            # decode runs through the version-portable shard_map pipeline
            # (replicated specs: every device steps the same batch — the
            # lowering path the sharded launch/step.py builders share)
            rep = jax.tree.map(lambda _: P(), (params, self.cache,
                                               self.cur_tok, self.pos))
            self._step = coll.sharded_jit(
                self._step_impl, mesh, rep, (P(), P()),
            )
        else:
            self._step = jax.jit(self._step_impl)

    def _step_impl(self, params, cache, tokens, pos):
        logits, cache = lm_decode_step(params, cache, tokens, pos, self.axes, self.cfg)
        nxt = tp_greedy(logits, self.axes)
        return nxt, cache

    def apply_wire_delta(self, words, alphas, wf, *, n_summed: int = 1):
        """Train→serve weight refresh over the integer wire.

        A trainer pushes a parameter delta as codec transport words
        (``wf.pack(wf.encode(Δx, α))`` per leaf — bits/8 bytes per
        coordinate for the packed codec instead of 4-byte floats); the
        serving replica decodes and applies it in place without ever
        receiving a float tensor. ``alphas`` is a pytree matching ``words``
        (or reusable scalars per leaf); ``n_summed`` is the number of summed
        payloads when the delta itself came off an all-reduce.
        """

        def leaf(p, w, a):
            ints = wf.unpack(w, p.shape, n_summed=n_summed)
            delta = wf.decode(ints, a, n_workers=n_summed)
            return (p.astype(jnp.float32) + delta).astype(p.dtype)

        self.params = jax.tree.map(leaf, self.params, words, alphas)

    def submit(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.pending:
                req = self.pending.pop(0)
                self.active[s] = req
                # prefill by stepping through the prompt (fills KV/state)
                for i, tok in enumerate(req.prompt):
                    self.cur_tok = self.cur_tok.at[s].set(tok)
                    self.pos = self.pos.at[s].set(i)
                    nxt, self.cache = self._step(
                        self.params, self.cache, self.cur_tok, self.pos
                    )
                req._next = int(nxt[s])
                self.pos = self.pos.at[s].set(len(req.prompt))
                self.cur_tok = self.cur_tok.at[s].set(req._next)
                req.out.append(req._next)

    def run(self, max_iters: int = 1000):
        it = 0
        while (self.pending or any(self.active)) and it < max_iters:
            it += 1
            self._admit()
            if not any(self.active):
                continue
            nxt, self.cache = self._step(self.params, self.cache, self.cur_tok, self.pos)
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nxt[s])
                req.out.append(tok)
                self.pos = self.pos.at[s].add(1)
                self.cur_tok = self.cur_tok.at[s].set(tok)
                if len(req.out) >= req.max_new or int(self.pos[s]) >= self.max_seq - 1:
                    req.done = True
                    self.active[s] = None
        return it
